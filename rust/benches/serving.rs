//! Serving sweep: goodput and SLO attainment under open-loop
//! heavy-tailed traffic, across arrival rate × replicas × store budget
//! × admission bound (EXPERIMENTS.md §Serving).
//!
//! Open-loop means arrivals never wait for completions — the generator
//! (serve::openloop) keeps injecting on its Pareto clock however far
//! the system falls behind, which is what makes overload visible:
//! closed-loop drivers self-throttle and hide it.  Goodput counts only
//! requests that finished inside the request SLO; attainment is the
//! fraction of requests meeting the TTFT deadline (and decode steps
//! meeting the ITL deadline).  Past saturation throughput keeps
//! climbing while goodput collapses — the gap between those two curves
//! is the figure.
//!
//! Sections:
//!   1. arrival rate × replicas — the goodput knee per replica count;
//!   2. arrival rate × store budget at fixed replicas — does the shared
//!      snapshot store move the knee;
//!   3. Pareto vs Poisson arrivals at the same mean rate — what the
//!      heavy tail alone costs in SLO attainment;
//!   4. admission bound sweep at overload — load shedding trades
//!      completed requests for restored TTFT attainment.
//!
//! Results land in bench_results/serving.json and, machine-readably for
//! the perf trajectory, BENCH_serving.json at the repo root (CI runs
//! this at smoke scale and uploads the artifact).
//!
//! Run: cargo bench --bench serving  [-- --smoke]

use icarus::bench_util::{write_results, Point, Row, KV_BPT_SMALL};
use icarus::cluster::Cluster;
use icarus::config::{ClusterRouting, ServingConfig, ServingMode, WorkloadConfig};
use icarus::engine::executor::CostModel;
use icarus::json::{self, Value};

const HOST_8MB: u64 = 8 << 20;
const DISK_256MB: u64 = 256 << 20;

fn serving_header() {
    println!(
        "{:<38} {:>9} {:>9} {:>9} {:>8} {:>8} {:>8}",
        "point", "tput_rps", "goodput", "ttft_att", "itl_att", "p95(s)", "rejected"
    );
}

fn print_serving_row(r: &Row, tput_rps: f64) {
    println!(
        "{:<38} {:>9.3} {:>9.3} {:>9.3} {:>8.3} {:>8.3} {:>8}",
        r.label, tput_rps, r.goodput_rps, r.ttft_attainment, r.itl_attainment, r.p95_s, r.rejected
    );
}

/// Run a section's points, printing the serving-centric table.
fn run_section(title: &str, points: &[Point]) -> Vec<Row> {
    println!("\n--- {title} ---");
    serving_header();
    let mut rows = Vec::new();
    for p in points {
        let stats = p.run();
        let row = Row::from_stats(p, &stats);
        print_serving_row(&row, stats.requests_per_s());
        rows.push(row);
    }
    rows
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let (qps_list, n_requests, replica_list): (&[f64], usize, &[usize]) = if smoke {
        (&[1.0, 4.0], 32, &[1, 4])
    } else {
        (&[0.5, 1.0, 2.0, 4.0, 8.0], 256, &[1, 2, 4])
    };
    let overload_qps = *qps_list.last().unwrap();

    println!(
        "== Serving sweep: open-loop Pareto traffic, goodput + SLO attainment, \
         ICaRus N=4{} ==",
        if smoke { " [smoke]" } else { "" }
    );

    let base = Point {
        mode: ServingMode::Icarus,
        n_models: 4,
        n_requests,
        kv_bytes_per_token: KV_BPT_SMALL,
        open_loop: true,
        pareto_alpha: 1.5,
        seed: 21,
        ..Default::default()
    };

    // 1: the goodput knee per replica count.  The gate is on (depth 64)
    // so overload sheds instead of queueing without bound.
    let mut points = Vec::new();
    for &replicas in replica_list {
        for &qps in qps_list {
            points.push(Point { qps, replicas, admit_queue: 64, ..base.clone() });
        }
    }
    let rows1 = run_section("goodput vs arrival rate x replicas (admit_queue=64)", &points);

    // 2: does the shared store move the knee at fixed replicas?  Same
    // memory-pressure regime as the overlap bench so restores happen.
    let store_budgets: &[(u64, u64, &str)] = &[
        (0, 0, "none"),
        (HOST_8MB, 0, "host8M"),
        (HOST_8MB, DISK_256MB, "host8M+disk256M"),
    ];
    let store_replicas = *replica_list.last().unwrap();
    let mut points2 = Vec::new();
    for &(host, disk, _) in store_budgets {
        for &qps in qps_list {
            points2.push(Point {
                qps,
                replicas: store_replicas,
                admit_queue: 64,
                kv_pool_bytes: 12 << 20,
                store_host_bytes: host,
                store_disk_bytes: disk,
                ..base.clone()
            });
        }
    }
    let title2 = format!("goodput vs arrival rate x store budget (R={store_replicas})");
    let rows2 = run_section(&title2, &points2);

    // 3: the heavy tail alone.  pareto_alpha <= 1 falls back to Poisson
    // in the generator, so both runs share every other knob and the
    // mean arrival rate.
    let mut points3 = Vec::new();
    for &alpha in &[1.0, 1.2, 1.5] {
        points3.push(Point {
            qps: overload_qps / 2.0,
            replicas: store_replicas,
            admit_queue: 64,
            pareto_alpha: alpha,
            ..base.clone()
        });
    }
    let title3 = "Pareto tail index vs Poisson (alpha=1.0) at the same mean rate";
    let rows3 = run_section(title3, &points3);

    // 4: admission bound at overload — shedding vs unbounded queueing.
    let mut points4 = Vec::new();
    for &admit_queue in &[0usize, 16, 64] {
        points4.push(Point {
            qps: overload_qps,
            replicas: store_replicas,
            admit_queue,
            ..base.clone()
        });
    }
    let title4 = format!("admission bound at overload (qps={overload_qps})");
    let rows4 = run_section(&title4, &points4);

    let mut rows = rows1;
    rows.extend(rows2);
    rows.extend(rows3);
    rows.extend(rows4);

    // Goodput/attainment curves keyed by sweep axis, for plotting
    // without re-deriving the sections from row labels.
    let curve = |rows: &[Row], points: &[Point]| -> Value {
        Value::Arr(
            points
                .iter()
                .zip(rows)
                .map(|(p, r)| {
                    json::obj(vec![
                        ("qps", json::num(p.qps)),
                        ("replicas", json::num(p.replicas as f64)),
                        ("store_host_bytes", json::num(p.store_host_bytes as f64)),
                        ("store_disk_bytes", json::num(p.store_disk_bytes as f64)),
                        ("pareto_alpha", json::num(p.pareto_alpha)),
                        ("admit_queue", json::num(p.admit_queue as f64)),
                        ("goodput_rps", json::num(r.goodput_rps)),
                        ("ttft_attainment", json::num(r.ttft_attainment)),
                        ("itl_attainment", json::num(r.itl_attainment)),
                        ("rejected", json::num(r.rejected as f64)),
                    ])
                })
                .collect(),
        )
    };
    write_results(
        "serving",
        &rows,
        vec![
            ("figure", json::s("serving-goodput-slo")),
            ("smoke", Value::Bool(smoke)),
            (
                "slo",
                json::obj(vec![
                    ("request_s", json::num(icarus::serve::DEFAULT_SLO_REQUEST_S)),
                    ("ttft_s", json::num(icarus::serve::DEFAULT_SLO_TTFT_S)),
                    ("itl_s", json::num(icarus::serve::DEFAULT_SLO_ITL_S)),
                ]),
            ),
            ("rate_x_replicas", curve(&rows[..points.len()], &points)),
            ("rate_x_store", {
                let off = points.len();
                curve(&rows[off..off + points2.len()], &points2)
            }),
            ("tail_ablation", {
                let off = points.len() + points2.len();
                curve(&rows[off..off + points3.len()], &points3)
            }),
            ("admission_ablation", {
                let off = points.len() + points2.len() + points3.len();
                curve(&rows[off..off + points4.len()], &points4)
            }),
        ],
    );

    // Smoke runs also emit a Perfetto trace of one obs-on
    // disaggregated run so CI can validate the exporter end to end
    // (tools/check_trace.py --require-kinds ...): disagg + a shared
    // store + clock-advancing restores cover all six span kinds.
    if smoke {
        let scfg = ServingConfig {
            obs: true,
            replicas: 4,
            disagg: true,
            prefill_replicas: 2,
            cluster_routing: ClusterRouting::PrefillDecode,
            kv_pool_bytes: 32 << 20,
            store_host_bytes: 512 << 20,
            ..Default::default()
        };
        let wcfg = WorkloadConfig {
            n_models: 4,
            qps: 1.5,
            n_requests: 48,
            seed: 21,
            ..Default::default()
        };
        let out = Cluster::new(scfg, KV_BPT_SMALL, wcfg.n_models)
            .run_sim(CostModel::default(), icarus::workload::generate(&wcfg));
        let text = icarus::obs::export_chrome_trace(&out.obs).to_string_pretty();
        // Repo root, next to the BENCH_ mirrors (same best-effort
        // rationale as bench_util::write_results) — but deliberately
        // not BENCH_-prefixed: it is a format fixture, not a result.
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../trace_smoke.json");
        match std::fs::write(&path, text) {
            Ok(()) => println!("wrote {}", path.display()),
            Err(e) => eprintln!("could not write {}: {e}", path.display()),
        }
    }
}
