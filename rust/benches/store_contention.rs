//! Shared-store lock contention: store-op throughput vs consumer
//! threads × lock-stripe shards × probe mix (EXPERIMENTS.md §Store
//! contention).
//!
//! What this demonstrates:
//!   * the single-lock snapshot store serializes every replica's
//!     probes, publishes and restores — the hottest structure in the
//!     ICaRus design scales *against* the consumer count;
//!   * lock striping (`--store-shards`, default 2× replicas) removes
//!     the serialization: at ≥4 threads, 8 shards beat the serial
//!     layout (shards = 1, bit-identical to the pre-shard store — see
//!     `prop_store_shards_bit_identical`) on every mix, most at
//!     write-heavy mixes where even the striped read path must queue
//!     behind same-shard writers;
//!   * probes take shard *read* locks, so probe-heavy mixes scale
//!     further than write-heavy ones at every shard count.
//!
//! This is a raw store microbenchmark — no engine, no virtual clock
//! fence — so the numbers isolate lock contention from sim work.
//! Chains are precomputed ([`chain_keys`]); hashing is off the
//! measured path, exactly as on the engine's memoized hot path
//! (`TokenBuf::block_chain`).
//!
//! Results land in bench_results/store_contention.json and, machine-
//! readably for the perf trajectory, BENCH_store_contention.json at
//! the repo root (CI runs this at smoke scale and uploads the
//! artifact).
//!
//! Run: cargo bench --bench store_contention  [-- --smoke]

use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use icarus::json::{self, Value};
use icarus::store::{chain_keys, BlockKey, SnapshotStore, TieredStore};

const BLOCK_TOKENS: usize = 16;
const KV_BPT: u64 = 64; // 1 KiB per block — accounting, not data

/// Deterministic per-thread op stream (splitmix64): which chain an op
/// touches and whether it probes or writes.
struct Rng(u64);

impl Rng {
    fn next(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9e37_79b9_7f4a_7c15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^ (z >> 31)
    }

    fn f64(&mut self) -> f64 {
        (self.next() >> 11) as f64 / (1u64 << 53) as f64
    }
}

/// The working set one thread hammers: a mix of thread-private chains
/// and chains extending prefixes shared by every thread (the
/// cross-replica dedup/reuse traffic the store exists for — and the
/// cross-shard lock traffic that makes striping earn its keep).
fn make_chains(thread: usize, shared: &[Vec<u32>]) -> Vec<Vec<BlockKey>> {
    let mut chains = Vec::new();
    for (i, prefix) in shared.iter().enumerate() {
        // Shared prefix extended per-thread: common roots, private tails.
        let mut ctx = prefix.clone();
        ctx.extend((0..32u32).map(|t| t * 7 + (thread as u32) * 131 + i as u32));
        chains.push(chain_keys(&ctx, BLOCK_TOKENS));
    }
    for i in 0..8u32 {
        // Fully private chains (2–5 blocks).
        let len = (2 + (i as usize % 4)) * BLOCK_TOKENS;
        let ctx: Vec<u32> =
            (0..len as u32).map(|t| t * 13 + (thread as u32) * 977 + i * 59 + 1).collect();
        chains.push(chain_keys(&ctx, BLOCK_TOKENS));
    }
    chains
}

/// Hammer `store` from `threads` workers for `ops` operations each:
/// `probe_rate` of them read-only peeks, the rest split between
/// publishes and restores.  Returns aggregate store operations per
/// wall-clock second.
fn run_mix(store: &Arc<TieredStore>, threads: usize, ops: usize, probe_rate: f64) -> f64 {
    let shared: Vec<Vec<u32>> =
        (0..4u32).map(|i| (0..64u32).map(|t| t * 3 + i * 10_007).collect()).collect();
    let start = Instant::now();
    std::thread::scope(|s| {
        for thread in 0..threads {
            let store = Arc::clone(store);
            let shared = &shared;
            s.spawn(move || {
                let chains = make_chains(thread, shared);
                let mut rng = Rng(0x5eed ^ ((thread as u64) << 32));
                // Warm the store so probes and restores have hits.
                for c in &chains {
                    store.publish_chain(c, 0.0, 0.0, thread);
                }
                for i in 0..ops {
                    let now = 1.0 + i as f64 * 1e-6;
                    let chain = &chains[(rng.next() as usize) % chains.len()];
                    let p = rng.f64();
                    if p < probe_rate {
                        std::hint::black_box(store.peek_chain(chain, now));
                    } else if p < probe_rate + (1.0 - probe_rate) * 0.5 {
                        store.publish_chain(chain, now, now, thread);
                    } else {
                        std::hint::black_box(store.restore_chain(
                            chain,
                            0,
                            now,
                            (thread + 1) % threads.max(1),
                        ));
                    }
                }
            });
        }
    });
    (threads * ops) as f64 / start.elapsed().as_secs_f64()
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let ops: usize = if smoke { 20_000 } else { 200_000 };
    let thread_list: &[usize] = &[1, 2, 4, 8];
    let shard_list: &[usize] = &[1, 2, 4, 8];
    let probe_rates: &[f64] = if smoke { &[0.9] } else { &[0.9, 0.5] };

    println!(
        "== Store contention: threads x shards x probe mix, {ops} ops/thread{} ==\n",
        if smoke { " [smoke]" } else { "" }
    );
    println!(
        "{:<28} {:>14} {:>12} {:>10}",
        "threads/shards/probe", "ops/s", "serial ops/s", "speedup"
    );

    let mut rows = Vec::new();
    for &probe_rate in probe_rates {
        for &threads in thread_list {
            let mut serial_ops_s = 0.0f64;
            for &shards in shard_list {
                // Budgets sized so the working set fits: contention,
                // not eviction, is the variable under test (eviction
                // upgrades to all-shard locking by design).
                let store = Arc::new(TieredStore::with_shards(
                    4096 * 1024,
                    0,
                    BLOCK_TOKENS,
                    KV_BPT,
                    shards,
                ));
                let ops_s = run_mix(&store, threads, ops, probe_rate);
                if shards == 1 {
                    // The serial baseline column: shards = 1 is the
                    // pre-shard single-lock layout (pinned bit-identical
                    // by prop_store_shards_bit_identical).
                    serial_ops_s = ops_s;
                }
                let speedup = if serial_ops_s > 0.0 { ops_s / serial_ops_s } else { 0.0 };
                println!(
                    "{:<28} {:>14.0} {:>12.0} {:>9.2}x",
                    format!("T={threads}/S={shards}/p={probe_rate:.1}"),
                    ops_s,
                    serial_ops_s,
                    speedup,
                );
                rows.push(json::obj(vec![
                    ("threads", json::num(threads as f64)),
                    ("shards", json::num(shards as f64)),
                    ("probe_rate", json::num(probe_rate)),
                    ("ops_per_thread", json::num(ops as f64)),
                    ("ops_per_s", json::num(ops_s)),
                    ("serial_baseline_ops_per_s", json::num(serial_ops_s)),
                    ("speedup_vs_serial", json::num(speedup)),
                ]));
            }
        }
    }

    // The acceptance row: highest contention point (max threads), does
    // max shards strictly beat the serial layout?
    let at = |threads: usize, shards: usize, probe: f64| -> f64 {
        rows.iter()
            .find_map(|r| match r {
                Value::Obj(kv) => {
                    let get = |k: &str| {
                        kv.iter().find(|(n, _)| n == k).and_then(|(_, v)| match v {
                            Value::Num(x) => Some(*x),
                            _ => None,
                        })
                    };
                    (get("threads") == Some(threads as f64)
                        && get("shards") == Some(shards as f64)
                        && get("probe_rate") == Some(probe))
                    .then(|| get("ops_per_s").unwrap_or(0.0))
                }
                _ => None,
            })
            .unwrap_or(0.0)
    };
    let top = *thread_list.last().expect("non-empty");
    let mut scaling = Vec::new();
    for &probe_rate in probe_rates {
        let serial = at(top, 1, probe_rate);
        let sharded = at(top, 8, probe_rate);
        println!(
            "\nT={top} p={probe_rate:.1}: shards=8 {:.0} ops/s vs serial {:.0} ops/s ({:.2}x)",
            sharded,
            serial,
            if serial > 0.0 { sharded / serial } else { 0.0 },
        );
        scaling.push(json::obj(vec![
            ("threads", json::num(top as f64)),
            ("probe_rate", json::num(probe_rate)),
            ("serial_ops_per_s", json::num(serial)),
            ("shards8_ops_per_s", json::num(sharded)),
            ("speedup", json::num(if serial > 0.0 { sharded / serial } else { 0.0 })),
        ]));
    }

    // Hand-rolled mirror (same layout/paths as bench_util::write_results,
    // which is coupled to engine-sweep Row objects; these rows are raw
    // store-op measurements).
    let doc = json::obj(vec![
        ("bench", json::s("store_contention")),
        ("rows", Value::Arr(rows)),
        ("figure", json::s("store scaling (ROADMAP: consumer-count scaling)")),
        ("baseline", json::s("shards=1 == pre-shard single-lock store")),
        ("smoke", Value::Bool(smoke)),
        ("sharded_vs_serial", Value::Arr(scaling)),
    ]);
    let dir = Path::new("bench_results");
    std::fs::create_dir_all(dir).ok();
    let path = dir.join("store_contention.json");
    std::fs::write(&path, doc.to_string_pretty()).expect("write results");
    println!("\nwrote {}", path.display());
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("..");
    let bench_path = root.join("BENCH_store_contention.json");
    match std::fs::write(&bench_path, doc.to_string_pretty()) {
        Ok(()) => println!("wrote {}", bench_path.display()),
        Err(e) => eprintln!("could not write {}: {e}", bench_path.display()),
    }
}
