//! Fig 8 / Appendix E reproduction: ReAct sweep with swap-based KV
//! eviction (4 GB swap tier) instead of recompute.
//!
//! Paper result (shape): ICaRus still wins (up to 12.1x lower P95, 3.8x
//! throughput with 8 models) because it reduces KV pressure itself, so
//! swap traffic is rarely triggered in the first place — recompute vs
//! swap is orthogonal to cross-model sharing.
//!
//! Run: cargo bench --bench fig8_swap

use icarus::bench_util::{summarize_pairs, sweep, write_results, Point, KV_BPT_SMALL};
use icarus::config::{EvictionPolicy, ServingMode};
use icarus::json;

fn main() {
    let qps_list = [0.2, 0.4, 0.8, 1.5, 3.0];
    let mut points = Vec::new();
    for &n in &[2usize, 4, 8] {
        for mode in [ServingMode::Baseline, ServingMode::Icarus] {
            for &qps in &qps_list {
                points.push(Point {
                    mode,
                    n_models: n,
                    qps,
                    eviction: EvictionPolicy::Swap,
                    kv_pool_bytes: 12 << 20,
                    kv_bytes_per_token: KV_BPT_SMALL,
                    ..Default::default()
                });
            }
        }
    }
    println!("== Fig 8: ReAct with swap-based eviction (4 GB swap tier, pool 12 MB) ==\n");
    let rows = sweep(&points);
    summarize_pairs(&rows);
    write_results(
        "fig8_swap",
        &rows,
        vec![("figure", json::s("8")), ("eviction", json::s("swap"))],
    );
}
