//! Hot-path microbenches (criterion is unavailable offline; this uses
//! `bench_util::measure`, a plain measure-loop with warmup +
//! median-of-runs):
//!
//!   * radix prefix tree lookup/insert at depth,
//!   * radix churn (insert/lookup/evict cycles) at 1k vs 10k resident
//!     nodes — the eviction-complexity check: with the heap-based
//!     evictable-leaf index the per-op cost stays ~flat as residency
//!     grows, where the old per-block arena scan scaled linearly,
//!   * block pool alloc/release,
//!   * engine step overhead with a zero-cost executor (pure scheduler),
//!   * PJRT prefill/decode step times (when artifacts exist) — these
//!     calibrate the SimExecutor cost model (EXPERIMENTS.md §Calibration).
//!
//! Run: cargo bench --bench micro_hotpath

use std::time::Instant;

use icarus::bench_util::measure;
use icarus::config::{ServingConfig, ServingMode, WorkloadConfig};
use icarus::engine::executor::{CostModel, DecodeSlot, Executor, SimExecutor};
use icarus::engine::Engine;
use icarus::json;
use icarus::kvcache::{BlockPool, RadixCache};
use icarus::rng::Rng;
use icarus::runtime::{Manifest, PjrtExecutor};
use icarus::workload::generate;

/// Steady-state churn at a fixed resident node count: one op = insert a
/// fresh 4-block context, look it up, evict 4 LRU blocks.  Returns
/// seconds per op.
fn radix_churn(resident_target: usize) -> f64 {
    const BLOCK_TOKENS: usize = 16;
    const BLOCKS_PER_CTX: usize = 4;
    const CTX_TOKENS: usize = BLOCKS_PER_CTX * BLOCK_TOKENS;
    let pool_bytes = (resident_target as u64 + 64) * BLOCK_TOKENS as u64 * 2048;
    let mut pool = BlockPool::new(pool_bytes, BLOCK_TOKENS, 2048);
    let mut radix = RadixCache::new();
    let mut rng = Rng::new(11);
    for i in 0..resident_target / BLOCKS_PER_CTX {
        let t: Vec<u32> = (0..CTX_TOKENS).map(|_| rng.below(1 << 20) as u32).collect();
        assert!(radix.insert(&t, i as u64, &mut pool));
    }
    assert!(radix.resident_nodes() + BLOCKS_PER_CTX >= resident_target);
    let mut salt = 0u64;
    measure(
        &format!("radix churn ins+lookup+evict @{:>6} nodes", radix.resident_nodes()),
        2000,
        || {
            salt += 1;
            let t: Vec<u32> = (0..CTX_TOKENS as u64)
                .map(|i| ((salt << 8).wrapping_add(i.wrapping_mul(2_654_435_761))) as u32)
                .collect();
            radix.insert(&t, salt, &mut pool);
            let m = radix.lookup(&t);
            assert!(m.matched_tokens >= CTX_TOKENS);
            radix.evict(BLOCKS_PER_CTX, &mut pool);
        },
    )
}

fn main() {
    println!("== micro: kv cache ==\n");
    let mut results = Vec::new();

    // Radix: populate 256 contexts of 256 tokens sharing a 48-token
    // system prefix, then time lookups.
    let mut pool = BlockPool::new(1u64 << 30, 16, 2048);
    let mut radix = RadixCache::new();
    let mut rng = Rng::new(1);
    let sys: Vec<u32> = (0..48).map(|i| i as u32).collect();
    let mut contexts = Vec::new();
    for i in 0..256 {
        let mut t = sys.clone();
        t.extend((0..208).map(|_| rng.below(1900) as u32));
        assert!(radix.insert(&t, i, &mut pool));
        contexts.push(t);
    }
    let mut idx = 0;
    let t = measure("radix lookup (256 ctxs x 256 tok)", 2000, || {
        idx = (idx + 1) % contexts.len();
        let m = radix.lookup(&contexts[idx]);
        assert!(m.matched_tokens >= 208);
    });
    results.push(("radix_lookup_us", t * 1e6));

    let mut salt = 0u32;
    let t = measure("radix insert+evict (64 tok)", 500, || {
        salt += 1;
        let mut t: Vec<u32> = sys.clone();
        t.extend((0..16).map(|i| i * 31 + salt));
        radix.insert(&t, u64::from(salt), &mut pool);
        radix.evict(1, &mut pool);
    });
    results.push(("radix_insert_evict_us", t * 1e6));

    // Churn at scale: eviction cost must not grow with residency.
    let churn_1k = radix_churn(1_000);
    let churn_10k = radix_churn(10_000);
    println!(
        "churn scaling 1k -> 10k resident nodes: {:.2}x per op (the old \
         per-block arena scan scaled ~10x here)",
        churn_10k / churn_1k
    );
    results.push(("radix_churn_1k_us", churn_1k * 1e6));
    results.push(("radix_churn_10k_us", churn_10k * 1e6));
    results.push(("radix_churn_scaling_10x_nodes", churn_10k / churn_1k));

    let mut pool2 = BlockPool::new(1 << 26, 16, 2048);
    let t = measure("pool alloc+release (8 blocks)", 10_000, || {
        let blocks = pool2.alloc(8).unwrap();
        for b in blocks {
            pool2.release(b);
        }
    });
    results.push(("pool_alloc_release_us", t * 1e6));

    println!("\n== micro: engine scheduler overhead ==\n");
    // Zero-cost executor -> wall time below is pure L3 scheduling.
    struct ZeroExec(SimExecutor);
    impl Executor for ZeroExec {
        fn prefill(
            &mut self,
            m: usize,
            p: &[u32],
            c: usize,
            b: Option<u64>,
        ) -> anyhow::Result<icarus::engine::executor::PrefillOut> {
            let mut out = self.0.prefill(m, p, c, b)?;
            out.duration = 1e-9;
            Ok(out)
        }
        fn prefill_chunk(
            &mut self,
            chunk: &mut icarus::engine::executor::ChunkSlot<'_>,
        ) -> anyhow::Result<f64> {
            self.0.prefill_chunk(chunk)?;
            Ok(1e-9)
        }
        fn decode(&mut self, batch: &mut [DecodeSlot]) -> anyhow::Result<f64> {
            self.0.decode(batch)?;
            Ok(1e-9)
        }
        fn snapshot(&mut self, c: u64) -> u64 {
            self.0.snapshot(c)
        }
        fn drop_snapshot(&mut self, s: u64) {
            self.0.drop_snapshot(s)
        }
        fn swap_in_cost(&self, b: u64) -> f64 {
            self.0.swap_in_cost(b)
        }
        fn mode(&self) -> ServingMode {
            self.0.mode()
        }
    }
    let wcfg = WorkloadConfig { n_models: 4, qps: 1000.0, n_requests: 64, ..Default::default() };
    let wl = generate(&wcfg);
    let total_tokens: usize = wl.iter().map(|w| w.total_gen_tokens()).sum();
    let t0 = Instant::now();
    let scfg = ServingConfig { kv_pool_bytes: 1 << 30, ..Default::default() };
    let exec = ZeroExec(SimExecutor::new(CostModel::default(), ServingMode::Icarus));
    let stats = Engine::new(scfg, 2048, 4, exec).run(wl);
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "engine overhead: {:.2} µs/generated-token ({} tokens, {:.3}s wall)",
        wall / total_tokens as f64 * 1e6,
        stats.generated_tokens,
        wall
    );
    results.push(("engine_overhead_us_per_token", wall / total_tokens as f64 * 1e6));

    println!("\n== micro: PJRT runtime (calibration source) ==\n");
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        let m = Manifest::load(&dir).unwrap();
        for config in ["serve-small", "serve-base"] {
            if m.spec(config).is_err() {
                continue;
            }
            for mode in [ServingMode::Baseline, ServingMode::Icarus] {
                let mut ex = PjrtExecutor::load(&m, config, mode, 1).unwrap();
                let prompt: Vec<u32> = (0..96u32).map(|i| 32 + i % 1900).collect();
                let t0 = Instant::now();
                let out = ex.prefill(0, &prompt, 0, None).unwrap();
                let prefill_t = t0.elapsed().as_secs_f64();
                let mut slot = DecodeSlot {
                    seq_id: 1,
                    model_id: 0,
                    cache: out.cache,
                    context_len: prompt.len(),
                    last_token: out.first_token,
                    next_token: 0,
                };
                // median decode-step time over 32 steps
                let mut times = Vec::new();
                for _ in 0..32 {
                    let mut b = std::slice::from_mut(&mut slot);
                    let t0 = Instant::now();
                    ex.decode(&mut b).unwrap();
                    times.push(t0.elapsed().as_secs_f64());
                    slot.context_len += 1;
                    slot.last_token = slot.next_token;
                }
                times.sort_by(f64::total_cmp);
                let med = times[times.len() / 2];
                println!(
                    "{config:<12} {:<9} prefill(96 tok) {:>8.2} ms   decode-step {:>8.2} ms",
                    mode.as_str(),
                    prefill_t * 1e3,
                    med * 1e3
                );
                results.push((
                    match (config, mode) {
                        ("serve-small", ServingMode::Baseline) => "pjrt_small_baseline_decode_ms",
                        ("serve-small", ServingMode::Icarus) => "pjrt_small_icarus_decode_ms",
                        ("serve-base", ServingMode::Baseline) => "pjrt_base_baseline_decode_ms",
                        _ => "pjrt_base_icarus_decode_ms",
                    },
                    med * 1e3,
                ));
            }
        }
    } else {
        println!("(artifacts missing — run `make artifacts` for PJRT calibration)");
    }

    std::fs::create_dir_all("bench_results").ok();
    let v = json::obj(
        results.iter().map(|(k, v)| (*k, json::num(*v))).collect::<Vec<_>>(),
    );
    std::fs::write("bench_results/micro_hotpath.json", v.to_string_pretty()).unwrap();
    println!("\nwrote bench_results/micro_hotpath.json");
}
