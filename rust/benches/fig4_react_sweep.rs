//! Fig 4 reproduction: P95 latency and throughput vs QPS under ReAct,
//! N ∈ {2, 4, 8} LoRA models, baseline vs ICaRus (LLaMA-3.1-8B stand-in,
//! round-robin routing, recompute eviction).
//!
//! Paper result (shape to reproduce): baseline P95 explodes and
//! throughput plateaus/declines once the N-times-duplicated KV caches
//! saturate GPU memory — earlier for larger N; ICaRus keeps scaling.
//! Max-throughput gains: 1.4x/2.3x/3.8x; P95 gains at baseline's peak:
//! 3.8x/5.1x/11.1x for N=2/4/8.
//!
//! Run: cargo bench --bench fig4_react_sweep

use icarus::bench_util::{summarize_pairs, sweep, write_results, Point, KV_BPT_SMALL};
use icarus::config::ServingMode;
use icarus::json;

fn main() {
    let qps_list = [0.2, 0.4, 0.8, 1.5, 3.0];
    let n_list = [2usize, 4, 8];
    let mut points = Vec::new();
    for &n in &n_list {
        for mode in [ServingMode::Baseline, ServingMode::Icarus] {
            for &qps in &qps_list {
                points.push(Point {
                    mode,
                    n_models: n,
                    qps,
                    kv_pool_bytes: 24 << 20,
                    kv_bytes_per_token: KV_BPT_SMALL,
                    ..Default::default()
                });
            }
        }
    }
    println!("== Fig 4: ReAct, LLaMA-8B stand-in (serve-small), pool 24 MB ==\n");
    let rows = sweep(&points);
    summarize_pairs(&rows);

    // Paper-style max-throughput comparison per N.
    println!("\n--- max throughput per (mode, N) ---");
    for &n in &n_list {
        let best = |mode: ServingMode| {
            rows.iter()
                .filter(|r| r.mode == mode && r.n_models == n)
                .map(|r| r.tput_tok_s)
                .fold(0.0f64, f64::max)
        };
        let b = best(ServingMode::Baseline);
        let i = best(ServingMode::Icarus);
        println!("N={n}: baseline {b:.1} tok/s, icarus {i:.1} tok/s ({:.2}x)", i / b);
    }
    write_results(
        "fig4_react_sweep",
        &rows,
        vec![("figure", json::s("4")), ("pattern", json::s("react"))],
    );
}
