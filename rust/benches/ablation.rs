//! Ablation benches for the repo's key design choices:
//!
//!   1. prefix caching off — isolates how much of ICaRus's win is the
//!      cross-model *prefix reuse* vs just smaller footprint;
//!   2. sequential vs parallel logical encoder+decoder — the paper §3.3
//!      claim that paired execution removes the naive 2x decode cost
//!      (icarus_decode_factor 2.0 vs 1.05);
//!   3. KV block size sweep — allocator granularity vs hit rate.
//!
//! Run: cargo bench --bench ablation

use icarus::bench_util::{header, print_row, write_results, Point, Row, KV_BPT_SMALL};
use icarus::config::{ServingConfig, ServingMode, WorkloadConfig};
use icarus::engine::executor::{CostModel, SimExecutor};
use icarus::engine::Engine;
use icarus::json;
use icarus::workload::generate;

fn main() {
    let mut rows = Vec::new();

    println!("== Ablation 1: prefix caching on/off (icarus, N=4, qps 0.6) ==\n");
    header();
    for prefix_caching in [true, false] {
        let p = Point {
            mode: ServingMode::Icarus,
            n_models: 4,
            qps: 0.6,
            prefix_caching,
            kv_pool_bytes: 24 << 20,
            kv_bytes_per_token: KV_BPT_SMALL,
            ..Default::default()
        };
        let s = p.run();
        let mut r = Row::from_stats(&p, &s);
        r.label = format!("prefix={}", if prefix_caching { "on" } else { "off" });
        print_row(&r);
        rows.push(r);
    }

    println!("\n== Ablation 2: paired vs sequential decode (paper §3.3) ==\n");
    header();
    for (label, factor) in [("paired(1.05x)", 1.05), ("sequential(2.0x)", 2.0)] {
        let mut cost = CostModel::default();
        cost.icarus_decode_factor = factor;
        let p = Point {
            mode: ServingMode::Icarus,
            n_models: 4,
            qps: 0.6,
            cost,
            kv_pool_bytes: 24 << 20,
            ..Default::default()
        };
        let s = p.run();
        let mut r = Row::from_stats(&p, &s);
        r.label = label.to_string();
        print_row(&r);
        rows.push(r);
    }

    println!("\n== Ablation 3: KV block size (icarus, N=4, qps 0.6) ==\n");
    header();
    for block_tokens in [4usize, 16, 64] {
        let scfg = ServingConfig {
            mode: ServingMode::Icarus,
            kv_pool_bytes: 24 << 20,
            block_tokens,
            ..Default::default()
        };
        let wcfg = WorkloadConfig { n_models: 4, qps: 0.6, n_requests: 128, ..Default::default() };
        let exec = SimExecutor::new(CostModel::default(), ServingMode::Icarus);
        let s = Engine::new(scfg, KV_BPT_SMALL, 4, exec).run(generate(&wcfg));
        let p = Point { mode: ServingMode::Icarus, n_models: 4, qps: 0.6, ..Default::default() };
        let mut r = Row::from_stats(&p, &s);
        r.label = format!("block={block_tokens}");
        print_row(&r);
        rows.push(r);
    }

    write_results("ablation", &rows, vec![("bench_kind", json::s("ablation"))]);
}
