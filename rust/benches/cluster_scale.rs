//! Cluster scaling bench: what the multi-replica layer buys.
//!
//! Three measurements:
//!
//!   1. Parallel sweep wall-clock — the same fixed 16-point grid (a
//!      Fig 4-style row) swept with 1/2/4/8 worker threads.  Points
//!      are independent seeded sims, so the rows are bit-identical;
//!      only the wall clock shrinks (near-linearly until points
//!      outnumber cores).
//!   2. Replica scaling — one overloaded workload served by a cluster
//!      of R ∈ {1, 2, 4, 8} replicas: merged P95 falls and delivered
//!      throughput rises as the per-replica arrival rate drops.
//!   3. Routing policies — the same cluster at R = 4 under
//!      round_robin / least_loaded / hash_prefix workflow routing.
//!
//! Run: cargo bench --bench cluster_scale

use std::time::Instant;

use icarus::bench_util::{sweep_parallel, Point, KV_BPT_SMALL};
use icarus::cluster::Cluster;
use icarus::config::{ClusterRouting, ServingConfig, ServingMode, WorkloadConfig};
use icarus::engine::executor::CostModel;
use icarus::json::{self, Value};
use icarus::workload::generate;

fn main() {
    let mut results: Vec<(String, Value)> = Vec::new();

    // -- 1: parallel sweep wall-clock ------------------------------------
    let mut points = Vec::new();
    for mode in [ServingMode::Baseline, ServingMode::Icarus] {
        for &qps in &[0.2, 0.4, 0.8, 1.5] {
            for &n in &[4usize, 8] {
                points.push(Point {
                    mode,
                    n_models: n,
                    qps,
                    kv_pool_bytes: 24 << 20,
                    kv_bytes_per_token: KV_BPT_SMALL,
                    ..Default::default()
                });
            }
        }
    }
    println!("== 1: parallel sweep wall-clock ({} points) ==", points.len());
    let mut base_wall = 0.0;
    for &threads in &[1usize, 2, 4, 8] {
        println!("\n-- threads={threads} --");
        let t0 = Instant::now();
        let rows = sweep_parallel(&points, threads);
        let wall = t0.elapsed().as_secs_f64();
        assert_eq!(rows.len(), points.len());
        if threads == 1 {
            base_wall = wall;
        }
        println!(
            "threads={threads}: {wall:.2}s wall, {:.2}x vs 1 thread",
            base_wall / wall
        );
        results.push((format!("sweep_wall_s_threads_{threads}"), json::num(wall)));
        results.push((format!("sweep_speedup_threads_{threads}"), json::num(base_wall / wall)));
    }

    // -- 2: replica scaling of one overloaded workload --------------------
    let wcfg = WorkloadConfig {
        n_models: 8,
        qps: 4.0,
        n_requests: 256,
        seed: 17,
        ..Default::default()
    };
    let workload = generate(&wcfg);
    println!("\n== 2: replica scaling (8 models, qps 4.0, 256 workflows, 32 MB/replica) ==\n");
    println!("{:>9} {:>10} {:>10} {:>14} {:>10}", "replicas", "p95(s)", "p50(s)", "tput(tok/s)", "hit-rate");
    for &r in &[1usize, 2, 4, 8] {
        let scfg = ServingConfig {
            replicas: r,
            kv_pool_bytes: 32 << 20,
            ..Default::default()
        };
        let out = Cluster::new(scfg, KV_BPT_SMALL, wcfg.n_models)
            .run_sim(CostModel::default(), workload.clone());
        let tl = out.merged.turn_latency.as_ref().unwrap();
        println!(
            "{:>9} {:>10.3} {:>10.3} {:>14.1} {:>10.3}",
            r,
            tl.p95(),
            tl.p50(),
            out.merged.throughput_tok_s(),
            out.merged.cache_hit_rate()
        );
        results.push((format!("cluster_p95_s_r{r}"), json::num(tl.p95())));
        results.push((format!("cluster_tput_tok_s_r{r}"), json::num(out.merged.throughput_tok_s())));
    }

    // -- 3: routing policies at R = 4 -------------------------------------
    println!("\n== 3: routing policies (4 replicas, same workload) ==\n");
    println!("{:>14} {:>10} {:>14} {:>10} {:>18}", "routing", "p95(s)", "tput(tok/s)", "hit-rate", "wf-per-replica");
    for routing in [
        ClusterRouting::RoundRobin,
        ClusterRouting::LeastLoaded,
        ClusterRouting::HashPrefix,
    ] {
        let scfg = ServingConfig {
            replicas: 4,
            cluster_routing: routing,
            kv_pool_bytes: 32 << 20,
            ..Default::default()
        };
        let out = Cluster::new(scfg, KV_BPT_SMALL, wcfg.n_models)
            .run_sim(CostModel::default(), workload.clone());
        let tl = out.merged.turn_latency.as_ref().unwrap();
        let counts: Vec<u64> = out.per_replica.iter().map(|s| s.completed_requests).collect();
        println!(
            "{:>14} {:>10.3} {:>14.1} {:>10.3} {:>18}",
            routing.as_str(),
            tl.p95(),
            out.merged.throughput_tok_s(),
            out.merged.cache_hit_rate(),
            format!("{counts:?}")
        );
        results.push((format!("routing_{}_p95_s", routing.as_str()), json::num(tl.p95())));
        results.push((
            format!("routing_{}_hit_rate", routing.as_str()),
            json::num(out.merged.cache_hit_rate()),
        ));
    }

    std::fs::create_dir_all("bench_results").ok();
    let v = json::obj(results.iter().map(|(k, v)| (k.as_str(), v.clone())).collect());
    std::fs::write("bench_results/cluster_scale.json", v.to_string_pretty()).unwrap();
    println!("\nwrote bench_results/cluster_scale.json");
}
