//! Cluster scaling bench: what the multi-replica layer buys.
//!
//! Four measurements:
//!
//!   1. Parallel sweep wall-clock — the same fixed 16-point grid (a
//!      Fig 4-style row) swept with 1/2/4/8 worker threads.  Points
//!      are independent seeded sims, so the rows are bit-identical;
//!      only the wall clock shrinks (near-linearly until points
//!      outnumber cores).
//!   2. Replica scaling — one overloaded workload served by a cluster
//!      of R ∈ {1, 2, 4, 8} replicas: merged P95 falls and delivered
//!      throughput rises as the per-replica arrival rate drops.
//!   3. Routing policies — the same cluster at R = 4 under
//!      round_robin / least_loaded / hash_prefix workflow routing.
//!   4. Disaggregated prefill/decode tiers — a long-prompt overload at
//!      R = 4 swept over prefill:decode ratio × QPS × store budget
//!      against the homogeneous cluster (same replicas, same store).
//!      Prefill interference is what disaggregation removes, so the
//!      tiered splits should win P95/throughput at high QPS and lose
//!      at low QPS where dedicated prefill replicas sit idle.
//!
//! Run: cargo bench --bench cluster_scale
//! `-- --smoke` shrinks every grid for CI-sized runs.

use std::time::Instant;

use icarus::bench_util::{self, sweep, sweep_parallel, Point, KV_BPT_SMALL};
use icarus::cluster::Cluster;
use icarus::config::{ClusterRouting, ServingConfig, ServingMode, WorkloadConfig};
use icarus::engine::executor::CostModel;
use icarus::json::{self, Value};
use icarus::workload::generate;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let mut results: Vec<(String, Value)> = Vec::new();

    // -- 1: parallel sweep wall-clock ------------------------------------
    let mut points = Vec::new();
    let (qps_grid_1, n_grid_1): (&[f64], &[usize]) =
        if smoke { (&[0.4, 1.5], &[4]) } else { (&[0.2, 0.4, 0.8, 1.5], &[4, 8]) };
    for mode in [ServingMode::Baseline, ServingMode::Icarus] {
        for &qps in qps_grid_1 {
            for &n in n_grid_1 {
                points.push(Point {
                    mode,
                    n_models: n,
                    qps,
                    n_requests: if smoke { 48 } else { 128 },
                    kv_pool_bytes: 24 << 20,
                    kv_bytes_per_token: KV_BPT_SMALL,
                    ..Default::default()
                });
            }
        }
    }
    println!("== 1: parallel sweep wall-clock ({} points) ==", points.len());
    let mut base_wall = 0.0;
    let thread_grid: &[usize] = if smoke { &[1, 2] } else { &[1, 2, 4, 8] };
    for &threads in thread_grid {
        println!("\n-- threads={threads} --");
        let t0 = Instant::now();
        let rows = sweep_parallel(&points, threads);
        let wall = t0.elapsed().as_secs_f64();
        assert_eq!(rows.len(), points.len());
        if threads == 1 {
            base_wall = wall;
        }
        println!(
            "threads={threads}: {wall:.2}s wall, {:.2}x vs 1 thread",
            base_wall / wall
        );
        results.push((format!("sweep_wall_s_threads_{threads}"), json::num(wall)));
        results.push((format!("sweep_speedup_threads_{threads}"), json::num(base_wall / wall)));
    }

    // -- 2: replica scaling of one overloaded workload --------------------
    let wcfg = WorkloadConfig {
        n_models: 8,
        qps: 4.0,
        n_requests: if smoke { 96 } else { 256 },
        seed: 17,
        ..Default::default()
    };
    let workload = generate(&wcfg);
    println!(
        "\n== 2: replica scaling (8 models, qps 4.0, {} workflows, 32 MB/replica) ==\n",
        wcfg.n_requests
    );
    println!("{:>9} {:>10} {:>10} {:>14} {:>10}", "replicas", "p95(s)", "p50(s)", "tput(tok/s)", "hit-rate");
    let replica_grid: &[usize] = if smoke { &[1, 2, 4] } else { &[1, 2, 4, 8] };
    for &r in replica_grid {
        let scfg = ServingConfig {
            replicas: r,
            kv_pool_bytes: 32 << 20,
            ..Default::default()
        };
        let out = Cluster::new(scfg, KV_BPT_SMALL, wcfg.n_models)
            .run_sim(CostModel::default(), workload.clone());
        let tl = out.merged.turn_latency.as_ref().unwrap();
        println!(
            "{:>9} {:>10.3} {:>10.3} {:>14.1} {:>10.3}",
            r,
            tl.p95(),
            tl.p50(),
            out.merged.throughput_tok_s(),
            out.merged.cache_hit_rate()
        );
        results.push((format!("cluster_p95_s_r{r}"), json::num(tl.p95())));
        results.push((format!("cluster_tput_tok_s_r{r}"), json::num(out.merged.throughput_tok_s())));
    }

    // -- 3: routing policies at R = 4 -------------------------------------
    println!("\n== 3: routing policies (4 replicas, same workload) ==\n");
    println!("{:>14} {:>10} {:>14} {:>10} {:>18}", "routing", "p95(s)", "tput(tok/s)", "hit-rate", "wf-per-replica");
    for routing in [
        ClusterRouting::RoundRobin,
        ClusterRouting::LeastLoaded,
        ClusterRouting::HashPrefix,
    ] {
        let scfg = ServingConfig {
            replicas: 4,
            cluster_routing: routing,
            kv_pool_bytes: 32 << 20,
            ..Default::default()
        };
        let out = Cluster::new(scfg, KV_BPT_SMALL, wcfg.n_models)
            .run_sim(CostModel::default(), workload.clone());
        let tl = out.merged.turn_latency.as_ref().unwrap();
        let counts: Vec<u64> = out.per_replica.iter().map(|s| s.completed_requests).collect();
        println!(
            "{:>14} {:>10.3} {:>14.1} {:>10.3} {:>18}",
            routing.as_str(),
            tl.p95(),
            out.merged.throughput_tok_s(),
            out.merged.cache_hit_rate(),
            format!("{counts:?}")
        );
        results.push((format!("routing_{}_p95_s", routing.as_str()), json::num(tl.p95())));
        results.push((
            format!("routing_{}_hit_rate", routing.as_str()),
            json::num(out.merged.cache_hit_rate()),
        ));
    }

    // -- 4: disaggregated prefill/decode tiers ----------------------------
    // Long prompts make prefill the interference source; every point
    // (homogeneous included) runs chunk=256 so the comparison isolates
    // the tier split, not chunking.  Each cell sweeps the homogeneous
    // cluster first, then every prefill:decode ratio of the same R.
    let replicas = 4usize;
    let (disagg_qps, disagg_stores): (&[f64], &[u64]) = if smoke {
        (&[4.0], &[512 << 20])
    } else {
        (&[2.0, 4.0], &[256 << 20, 1 << 30])
    };
    println!("\n== 4: disaggregated prefill/decode tiers (R={replicas}, long prompts) ==\n");
    let mut rows = Vec::new();
    for &store in disagg_stores {
        for &qps in disagg_qps {
            let base = Point {
                n_models: 8,
                qps,
                n_requests: if smoke { 96 } else { 256 },
                seed: 17,
                prompt_mean: 384.0,
                prompt_std: 96.0,
                prefill_chunk: 256,
                replicas,
                kv_pool_bytes: 32 << 20,
                store_host_bytes: store,
                ..Default::default()
            };
            let mut pts = vec![base.clone()];
            for p in 1..replicas {
                pts.push(Point {
                    disagg: true,
                    prefill_replicas: p,
                    cluster_routing: ClusterRouting::PrefillDecode,
                    ..base.clone()
                });
            }
            let cell = sweep(&pts);
            let homog = &cell[0];
            let best = cell[1..]
                .iter()
                .min_by(|a, b| a.p95_s.total_cmp(&b.p95_s))
                .expect("ratio rows");
            println!(
                "store={}M qps={qps:.1}: best split {} — p95 {:.2}x, tput {:.2}x vs homogeneous",
                store >> 20,
                best.label,
                if best.p95_s > 0.0 { homog.p95_s / best.p95_s } else { f64::INFINITY },
                if homog.tput_tok_s > 0.0 { best.tput_tok_s / homog.tput_tok_s } else { f64::INFINITY },
            );
            results.push((
                format!("disagg_best_p95_ratio_store{}m_qps{qps:.1}", store >> 20),
                json::num(if best.p95_s > 0.0 { homog.p95_s / best.p95_s } else { f64::INFINITY }),
            ));
            rows.extend(cell);
        }
    }

    let extra: Vec<(&str, Value)> = results.iter().map(|(k, v)| (k.as_str(), v.clone())).collect();
    bench_util::write_results("cluster_scale", &rows, extra);
}
