//! Overlap sweep: the cooperative task runtime (`--overlap on`) vs the
//! serial inline-transfer-charging loop, across prefetch × replicas ×
//! QPS (EXPERIMENTS.md §Overlap).
//!
//! What this demonstrates:
//!   * with `--overlap off` every store restore and swap-in is charged
//!     inline — the whole batch waits out the PCIe/NVMe window;
//!   * with `--overlap on` the restore flies as a task on the
//!     per-replica executor: other sequences keep decoding across the
//!     window and the restored turn joins the batch at its virtual
//!     completion time, so P95 drops and the stall/overlap split in
//!     the stats (`stalled_transfer_s` vs `overlapped_transfer_s`)
//!     shows where the transfer seconds went;
//!   * stacking `--store-prefetch` on top overlaps the staging too, so
//!     the two optimizations compose rather than compete.
//!
//! Results land in bench_results/overlap.json and, machine-readably
//! for the perf trajectory, BENCH_overlap.json at the repo root (CI
//! runs this at smoke scale and uploads the artifact).
//!
//! Run: cargo bench --bench overlap  [-- --smoke]

use icarus::bench_util::{sweep, write_results, Point, Row, KV_BPT_SMALL};
use icarus::config::{EvictionPolicy, ServingMode};
use icarus::json::{self, Value};

const HOST_8MB: u64 = 8 << 20;
const DISK_256MB: u64 = 256 << 20;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let (qps_list, n_requests, replica_list): (&[f64], usize, &[usize]) = if smoke {
        (&[0.8], 24, &[1, 4])
    } else {
        (&[0.8, 1.5], 96, &[1, 4])
    };

    // overlap × prefetch grid; every point carries the same tiered
    // store + memory-pressure config, so overlap is the only axis that
    // changes how transfer seconds are charged.
    let variants: &[(bool, bool)] = &[
        (false, false), // serial baseline
        (true, false),  // overlap alone
        (false, true),  // prefetch alone
        (true, true),   // both
    ];

    let mut points = Vec::new();
    for &replicas in replica_list {
        for &(overlap, prefetch) in variants {
            for &qps in qps_list {
                points.push(Point {
                    mode: ServingMode::Icarus,
                    n_models: 4,
                    qps,
                    n_requests,
                    // Fig-8's memory-pressure regime: a 12 MB pool per
                    // replica forces constant eviction between turns,
                    // so nearly every re-admission rides a restore.
                    kv_pool_bytes: 12 << 20,
                    kv_bytes_per_token: KV_BPT_SMALL,
                    eviction: EvictionPolicy::Recompute,
                    replicas,
                    store_host_bytes: HOST_8MB,
                    store_disk_bytes: DISK_256MB,
                    store_prefetch: prefetch,
                    overlap,
                    seed: 13,
                    ..Default::default()
                });
            }
        }
    }
    println!(
        "== Overlap sweep: cooperative runtime x prefetch x replicas vs serial transfer \
         charging, ICaRus N=4, host 8M + disk 256M, pool 12 MB/replica{} ==\n",
        if smoke { " [smoke]" } else { "" }
    );
    let rows = sweep(&points);

    // The acceptance comparison: overlap-on vs overlap-off at the same
    // replica count, prefetch setting and QPS.
    let find = |replicas: usize, overlap: bool, prefetch: bool, qps: f64| -> Option<&Row> {
        points
            .iter()
            .zip(&rows)
            .find(|(p, _)| {
                p.replicas == replicas
                    && p.overlap == overlap
                    && p.store_prefetch == prefetch
                    && p.qps == qps
            })
            .map(|(_, r)| r)
    };
    println!("\n--- overlap on vs off (same replicas, prefetch, qps) ---");
    let mut comparisons = Vec::new();
    for &replicas in replica_list {
        for &prefetch in &[false, true] {
            for &qps in qps_list {
                let Some(base) = find(replicas, false, prefetch, qps) else { continue };
                let Some(on) = find(replicas, true, prefetch, qps) else { continue };
                let speedup = if on.p95_s > 0.0 { base.p95_s / on.p95_s } else { 0.0 };
                println!(
                    "R={replicas} pf={prefetch} qps={qps:.2}: p95 {:.3}s -> {:.3}s \
                     ({speedup:.2}x), stalled {:.3}s, overlapped {:.3}s",
                    base.p95_s, on.p95_s, on.stalled_transfer_s, on.overlapped_transfer_s,
                );
                comparisons.push(json::obj(vec![
                    ("replicas", json::num(replicas as f64)),
                    ("store_prefetch", Value::Bool(prefetch)),
                    ("qps", json::num(qps)),
                    ("p95_serial_s", json::num(base.p95_s)),
                    ("p95_overlap_s", json::num(on.p95_s)),
                    ("p95_speedup", json::num(speedup)),
                    ("stalled_transfer_s", json::num(on.stalled_transfer_s)),
                    ("overlapped_transfer_s", json::num(on.overlapped_transfer_s)),
                    ("store_hits", json::num(on.store_hits as f64)),
                ]));
            }
        }
    }
    write_results(
        "overlap",
        &rows,
        vec![
            ("figure", json::s("8-overlap")),
            ("baseline", json::s("serial inline transfer charging (--overlap off)")),
            ("smoke", Value::Bool(smoke)),
            ("overlap_vs_serial", Value::Arr(comparisons)),
        ],
    );
}
