"""AOT pipeline tests: flatten/unflatten contracts and HLO text emission."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest  # noqa: F401

from compile import aot
from compile import model as M


CFG = M.TRAIN_TINY  # small config so lowering is fast


class TestFlattening:
    def test_params_roundtrip(self):
        params = M.init_params(CFG, jax.random.PRNGKey(0))
        flat = aot.flatten_params(CFG, params)
        assert len(flat) == len(aot.param_names(CFG))
        back = aot.unflatten_params(CFG, flat)
        for (a, b) in zip(jax.tree_util.tree_leaves(params),
                          jax.tree_util.tree_leaves(back)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_lora_roundtrip(self):
        lora = M.init_lora(CFG, jax.random.PRNGKey(1))
        flat = aot.flatten_lora(CFG, lora)
        assert len(flat) == len(aot.lora_names(CFG))
        back = aot.unflatten_lora(CFG, flat)
        for (a, b) in zip(jax.tree_util.tree_leaves(lora),
                          jax.tree_util.tree_leaves(back)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_param_names_unique_and_stable(self):
        names = aot.param_names(CFG)
        assert len(names) == len(set(names))
        assert names[0] == "embed" and names[-1] == "lm_head"

    def test_icarus_lora_subset_roundtrip(self):
        """The icarus decode artifact takes only q/o/mlp adapters; k/v
        are reconstructed as zeros (the frozen logical encoder)."""
        lora = M.init_lora(CFG, jax.random.PRNGKey(1))
        flat = aot.flatten_lora(CFG, lora, M.ICARUS_TARGETS)
        names = aot.lora_names(CFG, M.ICARUS_TARGETS)
        assert len(flat) == len(names)
        assert not any(".k." in n or ".v." in n for n in names)
        back = aot.unflatten_lora(CFG, flat, M.ICARUS_TARGETS)
        for layer_in, layer_out in zip(lora, back):
            for t in M.ICARUS_TARGETS:
                np.testing.assert_array_equal(
                    np.asarray(layer_in[t][0]), np.asarray(layer_out[t][0]))
            for t in ("k", "v"):
                assert float(jnp.abs(layer_out[t][0]).max()) == 0.0
                assert float(jnp.abs(layer_out[t][1]).max()) == 0.0


class TestLowering:
    def test_decode_lowers_to_hlo_text(self, tmp_path):
        fn = aot._decode_fn(CFG, "icarus", use_kernels=False)
        lowered = jax.jit(fn).lower(
            *aot._example_args(CFG, "decode", targets=M.ICARUS_TARGETS))
        text = aot.to_hlo_text(lowered)
        assert "ENTRY" in text and "f32" in text
        # text round-trips through a file (what rust reads)
        p = tmp_path / "decode.hlo.txt"
        p.write_text(text)
        assert p.stat().st_size > 1000

    def test_prefill_pads_cache_to_max_seq(self):
        fn = aot._prefill_fn(CFG, 32, use_kernels=False)
        params = M.init_params(CFG, jax.random.PRNGKey(0))
        flat = aot.flatten_params(CFG, params)
        lflat = aot.flatten_lora(CFG, M.zero_lora(CFG))
        tokens = jnp.zeros((32,), jnp.int32)
        kc, vc, logits = fn(tokens, jnp.int32(5), *flat, *lflat)
        assert kc.shape == (CFG.layers, CFG.max_seq, CFG.kv_heads,
                            CFG.head_dim)
        assert logits.shape == (CFG.vocab,)
        # padding region is zero
        assert float(jnp.abs(kc[:, 32:]).max()) == 0.0

    def test_build_writes_manifest(self, tmp_path):
        manifest = aot.build(str(tmp_path), kernels="ref", configs=(CFG,),
                             buckets=(32,))
        m = json.loads((tmp_path / "manifest.json").read_text())
        assert m["configs"][CFG.name]["decode_icarus"]
        assert os.path.exists(tmp_path / m["configs"][CFG.name]["weights"])
        assert m["configs"][CFG.name]["kv_bytes_per_token"] == \
            CFG.kv_bytes_per_token()
