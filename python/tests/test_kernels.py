"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal.

Hypothesis sweeps shapes; every kernel must match ``ref.py`` to float32
tolerance across GQA group factors, sequence lengths and block sizes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.icarus_linear import icarus_linear
from compile.kernels.icarus_attention import paired_decode_attention
from compile.kernels.prefill_attention import prefill_attention

SETTINGS = dict(max_examples=12, deadline=None)


def rand(key, shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape) * scale


class TestIcarusLinear:
    @settings(**SETTINGS)
    @given(
        t=st.sampled_from([1, 2, 5]),
        d_in=st.sampled_from([16, 64, 96]),
        d_out=st.sampled_from([32, 128, 176]),
        r=st.sampled_from([4, 8]),
        block_n=st.sampled_from([32, 128]),
    )
    def test_matches_ref(self, t, d_in, d_out, r, block_n):
        x = rand(0, (2, t, d_in))
        w = rand(1, (d_in, d_out))
        a = rand(2, (d_in, r))
        b = rand(3, (r, d_out), 0.3)
        got = icarus_linear(x, w, a, b, 2.0, block_n=block_n)
        want = ref.icarus_linear_ref(x, w, a, b, 2.0)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)

    def test_encoder_stream_ignores_adapter(self):
        """Stream 0 must be pure base — the frozen logical encoder."""
        x = rand(0, (2, 1, 32))
        w = rand(1, (32, 64))
        a, b = rand(2, (32, 8)), rand(3, (8, 64))
        got = icarus_linear(x, w, a, b, 2.0)
        np.testing.assert_allclose(got[0], x[0] @ w, rtol=1e-5, atol=1e-5)

    def test_zero_adapter_is_base(self):
        x = rand(0, (2, 3, 32))
        w = rand(1, (32, 64))
        a = jnp.zeros((32, 8))
        b = jnp.zeros((8, 64))
        got = icarus_linear(x, w, a, b, 2.0)
        want = jnp.einsum("btd,df->btf", x, w)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


class TestPairedDecodeAttention:
    @settings(**SETTINGS)
    @given(
        h=st.sampled_from([4, 8]),
        group=st.sampled_from([1, 2, 4]),
        dh=st.sampled_from([8, 16]),
        s=st.sampled_from([64, 128, 256]),
        posfrac=st.floats(0.0, 1.0),
        block_s=st.sampled_from([32, 64, 128]),
    )
    def test_matches_ref(self, h, group, dh, s, posfrac, block_s):
        kv = max(1, h // group)
        h = kv * group
        pos = jnp.int32(int(posfrac * (s - 1)))
        q = rand(0, (2, h, dh))
        k = rand(1, (s, kv, dh))
        v = rand(2, (s, kv, dh))
        got = paired_decode_attention(q, k, v, pos, kv, block_s=block_s)
        want = ref.paired_decode_attention_ref(q, k, v, pos, kv)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)

    def test_masks_future_positions(self):
        """Entries beyond pos must not leak into the output."""
        q = rand(0, (2, 4, 8))
        k = rand(1, (64, 2, 8))
        v = rand(2, (64, 2, 8))
        pos = jnp.int32(10)
        base = paired_decode_attention(q, k, v, pos, 2)
        k2 = k.at[11:].set(999.0)
        v2 = v.at[11:].set(-999.0)
        got = paired_decode_attention(q, k2, v2, pos, 2)
        np.testing.assert_allclose(got, base, rtol=1e-5, atol=1e-5)

    def test_streams_share_cache_read(self):
        """Equal queries in both streams -> identical outputs (one KV)."""
        qs = rand(0, (1, 4, 8))
        q = jnp.concatenate([qs, qs], axis=0)
        k = rand(1, (32, 2, 8))
        v = rand(2, (32, 2, 8))
        got = paired_decode_attention(q, k, v, jnp.int32(20), 2)
        np.testing.assert_allclose(got[0], got[1], rtol=1e-6, atol=1e-6)


class TestPrefillAttention:
    @settings(**SETTINGS)
    @given(
        s=st.sampled_from([32, 64, 128]),
        group=st.sampled_from([1, 2]),
        kv=st.sampled_from([2, 4]),
        dh=st.sampled_from([8, 16]),
        lenfrac=st.floats(0.1, 1.0),
        block=st.sampled_from([16, 32, 64]),
    )
    def test_matches_ref(self, s, group, kv, dh, lenfrac, block):
        h = kv * group
        true_len = jnp.int32(max(1, int(lenfrac * s)))
        q = rand(0, (s, h, dh))
        k = rand(1, (s, kv, dh))
        v = rand(2, (s, kv, dh))
        got = prefill_attention(q, k, v, true_len, kv, block_q=block,
                                block_k=block)
        want = ref.prefill_attention_ref(q, k, v, true_len, kv)
        tl = int(true_len)
        np.testing.assert_allclose(got[:tl], want[:tl], rtol=2e-5, atol=2e-5)

    def test_causality(self):
        """Position i must not see keys at j > i."""
        s, kv, dh = 32, 2, 8
        q = rand(0, (s, 4, dh))
        k = rand(1, (s, kv, dh))
        v = rand(2, (s, kv, dh))
        base = prefill_attention(q, k, v, jnp.int32(s), kv)
        k2 = k.at[17:].add(rand(5, (s - 17, kv, dh)))
        got = prefill_attention(q, k2, v, jnp.int32(s), kv)
        np.testing.assert_allclose(got[:17], base[:17], rtol=1e-5, atol=1e-5)
