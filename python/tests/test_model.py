"""L2 model invariants — the properties that make ICaRus work.

The critical ones:
  * cache identity — the KV cache produced by ICaRus decode is the *base
    model's* cache, independent of which adapter is loaded (this is the
    entire paper);
  * baseline divergence — a conventional adapter produces a different
    cache (why baseline multi-model serving can't share);
  * prefill/decode consistency with the full training forward.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

CFG = M.TRAIN_TINY


@pytest.fixture(scope="module")
def setup():
    params = M.init_params(CFG, jax.random.PRNGKey(0))
    lora = M.init_lora(CFG, jax.random.PRNGKey(1))
    # Give B factors real values so adapters actually do something.
    lora = [
        {t: (ab[0], jax.random.normal(jax.random.PRNGKey(i * 7 + j),
                                      ab[1].shape) * 0.05)
         for j, (t, ab) in enumerate(layer.items())}
        for i, layer in enumerate(lora)
    ]
    ilora = [
        {t: (ab if t in M.ICARUS_TARGETS
             else (jnp.zeros_like(ab[0]), jnp.zeros_like(ab[1])))
         for t, ab in layer.items()}
        for layer in lora
    ]
    tokens = jax.random.randint(jax.random.PRNGKey(3), (16,), 0, CFG.vocab)
    return params, lora, ilora, tokens


def _pad_cache(kc, vc, max_s=32):
    shape = (CFG.layers, max_s, CFG.kv_heads, CFG.head_dim)
    return (jnp.zeros(shape).at[:, : kc.shape[1]].set(kc),
            jnp.zeros(shape).at[:, : vc.shape[1]].set(vc))


class TestCacheIdentity:
    def test_icarus_cache_is_base_cache(self, setup):
        """Two different ICaRus adapters write byte-identical cache."""
        params, lora, ilora, tokens = setup
        zl = M.zero_lora(CFG)
        kc, vc, _ = M.prefill(CFG, params, zl, tokens, jnp.int32(10))
        kcp, vcp = _pad_cache(kc, vc)
        ilora2 = [
            {t: (a * 2.0, b * -1.5) for t, (a, b) in layer.items()}
            for layer in ilora
        ]
        _, k1, v1 = M.decode_icarus(CFG, params, ilora, tokens[10],
                                    jnp.int32(10), kcp, vcp,
                                    use_kernels=False)
        _, k2, v2 = M.decode_icarus(CFG, params, ilora2, tokens[10],
                                    jnp.int32(10), kcp, vcp,
                                    use_kernels=False)
        np.testing.assert_array_equal(np.asarray(k1), np.asarray(k2))
        np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))

    def test_icarus_cache_matches_base_decode(self, setup):
        """ICaRus's written cache entry == pure base model's entry."""
        params, lora, ilora, tokens = setup
        zl = M.zero_lora(CFG)
        kc, vc, _ = M.prefill(CFG, params, zl, tokens, jnp.int32(10))
        kcp, vcp = _pad_cache(kc, vc)
        _, kb, vb = M.decode_baseline(CFG, params, zl, tokens[10],
                                      jnp.int32(10), kcp, vcp)
        _, ki, vi = M.decode_icarus(CFG, params, ilora, tokens[10],
                                    jnp.int32(10), kcp, vcp,
                                    use_kernels=False)
        np.testing.assert_allclose(np.asarray(ki[:, 10]),
                                   np.asarray(kb[:, 10]),
                                   rtol=1e-5, atol=1e-5)

    def test_baseline_cache_is_model_specific(self, setup):
        """A conventional adapter perturbs the cache — no sharing."""
        params, lora, ilora, tokens = setup
        zl = M.zero_lora(CFG)
        kc, vc, _ = M.prefill(CFG, params, zl, tokens, jnp.int32(10))
        kcp, vcp = _pad_cache(kc, vc)
        _, kb, _ = M.decode_baseline(CFG, params, zl, tokens[10],
                                     jnp.int32(10), kcp, vcp)
        _, kl, _ = M.decode_baseline(CFG, params, lora, tokens[10],
                                     jnp.int32(10), kcp, vcp)
        assert float(jnp.abs(kl[:, 10] - kb[:, 10]).max()) > 1e-4

    def test_prefill_cache_model_specific_with_adapter(self, setup):
        params, lora, ilora, tokens = setup
        zl = M.zero_lora(CFG)
        kc0, _, _ = M.prefill(CFG, params, zl, tokens, jnp.int32(10))
        kc1, _, _ = M.prefill(CFG, params, lora, tokens, jnp.int32(10))
        assert float(jnp.abs(kc1 - kc0).max()) > 1e-4


class TestConsistency:
    def test_prefill_logits_match_forward(self, setup):
        params, _, _, tokens = setup
        zl = M.zero_lora(CFG)
        _, _, logits = M.prefill(CFG, params, zl, tokens, jnp.int32(10))
        full = M.forward_base(CFG, params, tokens[None])[0]
        np.testing.assert_allclose(logits, full[9], rtol=1e-4, atol=1e-4)

    def test_decode_baseline_matches_forward(self, setup):
        params, lora, _, tokens = setup
        kc, vc, _ = M.prefill(CFG, params, lora, tokens, jnp.int32(10))
        kcp, vcp = _pad_cache(kc, vc)
        lg, _, _ = M.decode_baseline(CFG, params, lora, tokens[10],
                                     jnp.int32(10), kcp, vcp)
        full = M.forward_conventional(CFG, params, lora, tokens[None])[0]
        np.testing.assert_allclose(lg, full[10], rtol=1e-3, atol=1e-3)

    def test_decode_icarus_matches_forward_icarus(self, setup):
        params, _, ilora, tokens = setup
        zl = M.zero_lora(CFG)
        kc, vc, _ = M.prefill(CFG, params, zl, tokens, jnp.int32(10))
        kcp, vcp = _pad_cache(kc, vc)
        lg, _, _ = M.decode_icarus(CFG, params, ilora, tokens[10],
                                   jnp.int32(10), kcp, vcp,
                                   use_kernels=False)
        full = M.forward_icarus(CFG, params, ilora, tokens[None])[0]
        np.testing.assert_allclose(lg, full[10], rtol=1e-3, atol=1e-3)

    def test_kernel_path_matches_ref_path(self, setup):
        params, _, ilora, tokens = setup
        zl = M.zero_lora(CFG)
        kc, vc, _ = M.prefill(CFG, params, zl, tokens, jnp.int32(10))
        kcp, vcp = _pad_cache(kc, vc)
        lr_, kr, vr = M.decode_icarus(CFG, params, ilora, tokens[10],
                                      jnp.int32(10), kcp, vcp,
                                      use_kernels=False)
        lk, kk, vk = M.decode_icarus(CFG, params, ilora, tokens[10],
                                     jnp.int32(10), kcp, vcp,
                                     use_kernels=True)
        np.testing.assert_allclose(lk, lr_, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(kk), np.asarray(kr),
                                   rtol=1e-5, atol=1e-5)

    def test_kernel_prefill_matches_ref(self, setup):
        params, _, _, tokens = setup
        zl = M.zero_lora(CFG)
        kr, vr, lr_ = M.prefill(CFG, params, zl, tokens, jnp.int32(10))
        kk, vk, lk = M.prefill(CFG, params, zl, tokens, jnp.int32(10),
                               use_kernels=True)
        np.testing.assert_allclose(lk, lr_, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(kk), np.asarray(kr),
                                   rtol=1e-5, atol=1e-5)

    def test_multi_step_decode_chain(self, setup):
        """Three chained ICaRus decode steps == teacher-forced forward."""
        params, _, ilora, tokens = setup
        zl = M.zero_lora(CFG)
        kc, vc, _ = M.prefill(CFG, params, zl, tokens, jnp.int32(10))
        kcp, vcp = _pad_cache(kc, vc)
        full = M.forward_icarus(CFG, params, ilora, tokens[None])[0]
        for pos in (10, 11, 12):
            lg, kcp, vcp = M.decode_icarus(
                CFG, params, ilora, tokens[pos], jnp.int32(pos), kcp, vcp,
                use_kernels=False)
            np.testing.assert_allclose(lg, full[pos], rtol=1e-3, atol=2e-3)


class TestRope:
    def test_rope_is_rotation(self):
        """RoPE preserves norms."""
        x = jax.random.normal(jax.random.PRNGKey(0), (5, 4, 16))
        y = M.rope(x, jnp.arange(5), 10000.0)
        np.testing.assert_allclose(
            jnp.linalg.norm(x, axis=-1), jnp.linalg.norm(y, axis=-1),
            rtol=1e-5, atol=1e-5)

    def test_rope_relative_position(self):
        """<rope(q,i), rope(k,j)> depends only on i-j."""
        q = jax.random.normal(jax.random.PRNGKey(0), (1, 1, 16))
        k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 16))
        def dot(i, j):
            qi = M.rope(q, jnp.array([i]), 10000.0)
            kj = M.rope(k, jnp.array([j]), 10000.0)
            return float(jnp.sum(qi * kj))
        assert abs(dot(3, 1) - dot(7, 5)) < 1e-4
        assert abs(dot(3, 1) - dot(4, 1)) > 1e-6
