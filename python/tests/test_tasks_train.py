"""Task-suite and training-stack tests (accuracy-experiment substrate)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model as M
from compile import tasks as T
from compile import train as TR


class TestTasks:
    @settings(max_examples=20, deadline=None)
    @given(task=st.sampled_from(list(T.GENERATORS)), seed=st.integers(0, 999),
           hard=st.booleans())
    def test_examples_well_formed(self, task, seed, hard):
        rng = np.random.default_rng(seed)
        ex = T.GENERATORS[task](rng, 48, hard)
        assert ex.tokens.shape == (48,)
        assert ex.mask.shape == (48,)
        # answer span is exactly the masked span
        assert ex.mask[ex.prompt_len] == 1.0
        assert ex.mask[: ex.prompt_len].sum() == 0
        n_ans = int(ex.mask.sum())
        assert n_ans == len(ex.answer)
        assert ex.tokens[ex.prompt_len + n_ans - 1] == T.EOS
        # all tokens in vocab
        assert ex.tokens.max() < 256 and ex.tokens.min() >= 0

    def test_math_is_deterministic_mod(self):
        rng = np.random.default_rng(0)
        for _ in range(50):
            ex = T.gen_math(rng, 48)
            a = ex.tokens[2] - T.DIGIT0
            op = ex.tokens[3]
            b = ex.tokens[4] - T.DIGIT0
            val = (a + b) % T.MOD if op == T.OP_ADD else (a - b) % T.MOD
            assert ex.answer[0] == T.DIGIT0 + val

    def test_code_answer_closes_brackets(self):
        rng = np.random.default_rng(1)
        match = {T.OPEN_A: T.CLOSE_A, T.OPEN_B: T.CLOSE_B}
        for _ in range(50):
            ex = T.gen_code(rng, 48)
            body = list(ex.tokens[2: ex.prompt_len - 1])
            stack = []
            for t in body:
                if t in match:
                    stack.append(match[t])
                else:
                    assert stack.pop() == t
            want = list(reversed(stack)) if stack else [T.SEP]
            assert ex.answer[:-1] == want  # strip EOS

    def test_know_two_hop_consistent_with_kb(self):
        rng = np.random.default_rng(2)
        for _ in range(50):
            ex = T.gen_know(rng, 48, hard=True)
            e = ex.tokens[2] - T.ENTITY0
            a1 = ex.tokens[3] - T.ATTR0
            a2 = ex.tokens[4] - T.ATTR0
            _, e2 = T.KB.table[e][a1]
            _, v = T.KB.table[e2][a2]
            assert ex.answer[0] == T.VALUE0 + v

    def test_tool_args_sorted(self):
        rng = np.random.default_rng(3)
        for _ in range(50):
            ex = T.gen_tool(rng, 48)
            args = [t for t in ex.answer if t >= T.ARG0]
            assert args == sorted(args)

    def test_batch_shapes(self):
        rng = np.random.default_rng(0)
        toks, mask, exs = T.batch("math", rng, 8, 48)
        assert toks.shape == (8, 48) and mask.shape == (8, 48)
        assert len(exs) == 8


class TestTraining:
    def test_adam_decreases_quadratic(self):
        params = {"x": jnp.array([5.0, -3.0])}
        opt = TR.adam_init(params)
        for _ in range(200):
            grads = {"x": 2 * params["x"]}
            params, opt = TR.adam_update(grads, opt, params, 0.1,
                                         weight_decay=0.0)
        assert float(jnp.abs(params["x"]).max()) < 0.1

    def test_icarus_finetune_never_touches_kv_adapters(self):
        cfg = M.TRAIN_TINY
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        lora, _ = TR.finetune(cfg, params, "math", "icarus", 5, 8, 48)
        for layer in lora:
            for t in ("k", "v"):
                a, b = layer[t]
                assert float(jnp.abs(a).max()) == 0.0
                assert float(jnp.abs(b).max()) == 0.0

    def test_conventional_finetune_moves_kv_adapters(self):
        cfg = M.TRAIN_TINY
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        lora, _ = TR.finetune(cfg, params, "math", "conventional", 5, 8, 48)
        moved = any(float(jnp.abs(layer[t][1]).max()) > 0
                    for layer in lora for t in ("k", "v"))
        assert moved

    def test_losses_decrease(self):
        cfg = M.TRAIN_TINY
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        for method in ("conventional", "icarus"):
            _, losses = TR.finetune(cfg, params, "know", method, 40, 16, 32,
                                    lr=5e-3)
            assert losses[-1] < losses[0]

    def test_evaluate_range(self):
        cfg = M.TRAIN_TINY
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        acc = TR.evaluate(cfg, params, M.zero_lora(cfg), "conventional",
                          "gsm8k", 20, 48)
        assert 0.0 <= acc <= 100.0
