"""AOT lowering: JAX model -> HLO *text* artifacts + weights npz + manifest.

Python runs once at build time (``make artifacts``); the Rust coordinator
loads the HLO text via ``HloModuleProto::from_text_file`` and the weights
via the xla crate's npz reader, then executes with device-resident
buffers.  HLO text (not ``.serialize()``) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids that xla_extension 0.5.1
rejects; the text parser reassigns ids (see /opt/xla-example/README.md).

Artifacts per serving config:
  * ``prefill_{cfg}_{bucket}.hlo.txt``  — one per prompt-length bucket.
  * ``decode_baseline_{cfg}.hlo.txt``   — conventional LoRA decode step.
  * ``decode_icarus_{cfg}.hlo.txt``     — ICaRus paired decode step.
  * ``weights_{cfg}.npz``               — base model parameters.
  * ``manifest.json``                   — configs, argument orders, files.

Argument order (all artifacts): positional leading args, then the flat
base-parameter list, then the flat LoRA list (see ``flatten_params`` /
``flatten_lora``).  Weights are runtime arguments rather than baked
constants so the HLO text stays small and one artifact serves any
checkpoint.
"""

from __future__ import annotations

import argparse
import json
import os
from typing import List

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M

PREFILL_BUCKETS = (32, 64, 128, 256, 512)
SERVE_CONFIGS = (M.SERVE_SMALL, M.SERVE_BASE)

PARAM_ORDER_LAYER = (
    "attn_norm", "wq", "wk", "wv", "wo", "mlp_norm", "w_gate", "w_up",
    "w_down",
)


def flatten_params(cfg: M.ModelConfig, params: M.Params) -> List[jnp.ndarray]:
    """Deterministic flat ordering of base parameters (manifest contract)."""
    out = [params["embed"]]
    for layer in params["layers"]:
        out.extend(layer[k] for k in PARAM_ORDER_LAYER)
    out.append(params["norm"])
    out.append(params["lm_head"])
    return out


def param_names(cfg: M.ModelConfig) -> List[str]:
    names = ["embed"]
    for i in range(cfg.layers):
        names.extend(f"layers.{i}.{k}" for k in PARAM_ORDER_LAYER)
    names.extend(["norm", "lm_head"])
    return names


def unflatten_params(cfg: M.ModelConfig, flat) -> M.Params:
    flat = list(flat)
    embed = flat.pop(0)
    layers = []
    for _ in range(cfg.layers):
        layers.append({k: flat.pop(0) for k in PARAM_ORDER_LAYER})
    return {"embed": embed, "layers": layers, "norm": flat.pop(0),
            "lm_head": flat.pop(0)}


def flatten_lora(cfg: M.ModelConfig, lora: M.Lora,
                 targets=M.LORA_TARGETS) -> List[jnp.ndarray]:
    out = []
    for layer in lora:
        for t in targets:
            out.extend(layer[t])
    return out


def lora_names(cfg: M.ModelConfig, targets=M.LORA_TARGETS) -> List[str]:
    names = []
    for i in range(cfg.layers):
        for t in targets:
            names.extend([f"layers.{i}.{t}.A", f"layers.{i}.{t}.B"])
    return names


def unflatten_lora(cfg: M.ModelConfig, flat,
                   targets=M.LORA_TARGETS) -> M.Lora:
    """Rebuild the per-layer dict; targets not in `targets` get zeros.

    The ICaRus decode artifact only takes the logical-decoder targets
    (q,o,gate,up,down) as arguments — jax would DCE unused k/v adapter
    parameters out of the lowered module anyway, so the artifact
    signature must match exactly what the computation reads.
    """
    flat = list(flat)
    dims = {
        "q": (cfg.d_model, cfg.q_dim),
        "k": (cfg.d_model, cfg.kv_dim),
        "v": (cfg.d_model, cfg.kv_dim),
        "o": (cfg.q_dim, cfg.d_model),
        "gate": (cfg.d_model, cfg.ffn),
        "up": (cfg.d_model, cfg.ffn),
        "down": (cfg.ffn, cfg.d_model),
    }
    out = []
    for _ in range(cfg.layers):
        layer = {}
        for t in M.LORA_TARGETS:
            if t in targets:
                a = flat.pop(0)
                b = flat.pop(0)
            else:
                din, dout = dims[t]
                a = jnp.zeros((din, cfg.lora_rank), jnp.float32)
                b = jnp.zeros((cfg.lora_rank, dout), jnp.float32)
            layer[t] = (a, b)
        out.append(layer)
    return out


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _prefill_fn(cfg: M.ModelConfig, bucket: int, use_kernels: bool):
    n_params = len(param_names(cfg))

    def fn(tokens, true_len, *flat):
        params = unflatten_params(cfg, flat[:n_params])
        lora = unflatten_lora(cfg, flat[n_params:])
        kc, vc, logits = M.prefill(cfg, params, lora, tokens, true_len,
                                   use_kernels=use_kernels)
        # Pad the bucket-length cache to max_seq so rust can feed it
        # straight into the decode artifact.
        pad = cfg.max_seq - bucket
        kc = jnp.pad(kc, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vc = jnp.pad(vc, ((0, 0), (0, pad), (0, 0), (0, 0)))
        return kc, vc, logits

    return fn


def _decode_fn(cfg: M.ModelConfig, mode: str, use_kernels: bool):
    n_params = len(param_names(cfg))
    targets = M.LORA_TARGETS if mode == "baseline" else M.ICARUS_TARGETS

    def fn(token, pos, k_cache, v_cache, *flat):
        params = unflatten_params(cfg, flat[:n_params])
        lora = unflatten_lora(cfg, flat[n_params:], targets)
        if mode == "baseline":
            return M.decode_baseline(cfg, params, lora, token, pos,
                                     k_cache, v_cache)
        return M.decode_icarus(cfg, params, lora, token, pos, k_cache,
                               v_cache, use_kernels=use_kernels)

    return fn


def _example_args(cfg: M.ModelConfig, kind: str, bucket: int = 0,
                  targets=M.LORA_TARGETS):
    f32 = jnp.float32
    params = [jax.ShapeDtypeStruct(p.shape, f32)
              for p in flatten_params(cfg, M.init_params(cfg, jax.random.PRNGKey(0)))]
    lora = [jax.ShapeDtypeStruct(p.shape, f32)
            for p in flatten_lora(cfg, M.zero_lora(cfg), targets)]
    cache = jax.ShapeDtypeStruct(
        (cfg.layers, cfg.max_seq, cfg.kv_heads, cfg.head_dim), f32)
    i32 = jnp.int32
    if kind == "prefill":
        return (jax.ShapeDtypeStruct((bucket,), i32),
                jax.ShapeDtypeStruct((), i32), *params, *lora)
    return (jax.ShapeDtypeStruct((), i32), jax.ShapeDtypeStruct((), i32),
            cache, cache, *params, *lora)


def build(out_dir: str, kernels: str = "pallas", configs=SERVE_CONFIGS,
          buckets=PREFILL_BUCKETS, seed: int = 42) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    use_kernels = kernels == "pallas"
    manifest = {
        "kernels": kernels,
        "prefill_buckets": list(buckets),
        "param_order_layer": list(PARAM_ORDER_LAYER),
        "lora_targets": list(M.LORA_TARGETS),
        "icarus_targets": list(M.ICARUS_TARGETS),
        "configs": {},
    }
    for cfg in configs:
        params = M.init_params(cfg, jax.random.PRNGKey(seed))
        weights_file = f"weights_{cfg.name}.npz"
        np.savez(
            os.path.join(out_dir, weights_file),
            **{n: np.asarray(p) for n, p in
               zip(param_names(cfg), flatten_params(cfg, params))},
        )
        entry = {
            "vocab": cfg.vocab, "d_model": cfg.d_model,
            "layers": cfg.layers, "heads": cfg.heads,
            "kv_heads": cfg.kv_heads, "head_dim": cfg.head_dim,
            "ffn": cfg.ffn, "max_seq": cfg.max_seq,
            "lora_rank": cfg.lora_rank, "lora_alpha": cfg.lora_alpha,
            "kv_bytes_per_token": cfg.kv_bytes_per_token(),
            "param_count": cfg.param_count(),
            "weights": weights_file,
            "param_names": param_names(cfg),
            "lora_names": lora_names(cfg),
            "lora_names_icarus": lora_names(cfg, M.ICARUS_TARGETS),
            "prefill": {},
        }
        for bucket in buckets:
            if bucket > cfg.max_seq:
                continue
            name = f"prefill_{cfg.name}_{bucket}.hlo.txt"
            lowered = jax.jit(_prefill_fn(cfg, bucket, use_kernels)).lower(
                *_example_args(cfg, "prefill", bucket))
            with open(os.path.join(out_dir, name), "w") as f:
                f.write(to_hlo_text(lowered))
            entry["prefill"][str(bucket)] = name
            print(f"wrote {name}")
        for mode in ("baseline", "icarus"):
            name = f"decode_{mode}_{cfg.name}.hlo.txt"
            targets = M.LORA_TARGETS if mode == "baseline" else M.ICARUS_TARGETS
            lowered = jax.jit(_decode_fn(cfg, mode, use_kernels)).lower(
                *_example_args(cfg, "decode", targets=targets))
            with open(os.path.join(out_dir, name), "w") as f:
                f.write(to_hlo_text(lowered))
            entry[f"decode_{mode}"] = name
            print(f"wrote {name}")
        manifest["configs"][cfg.name] = entry
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest.json ({len(manifest['configs'])} configs)")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--kernels", choices=("pallas", "ref"), default="ref",
                    help="lowering path for the attention/linear hot-spots. "
                    "'ref' (default) is the mathematically identical jnp "
                    "path — interpret-mode Pallas lowers to per-grid-step "
                    "while loops that are ~1.4-1.7x slower on CPU PJRT "
                    "(EXPERIMENTS.md §Perf); the kernels stay verified "
                    "against ref by pytest and are the TPU lowering path.")
    ap.add_argument("--configs", nargs="*", default=None,
                    help="subset of serving configs to build")
    args = ap.parse_args()
    configs = SERVE_CONFIGS
    if args.configs:
        configs = tuple(M.CONFIGS[c] for c in args.configs)
    build(args.out_dir, kernels=args.kernels, configs=configs)


if __name__ == "__main__":
    main()
