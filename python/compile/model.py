"""L2: ICaRus decoder-only Transformer in JAX.

A complete LLaMA-family architecture (RMSNorm, RoPE, GQA, SwiGLU, untied
LM head) with LoRA adapters, exposing the four entry points the serving
system compiles AOT:

  * ``prefill``          — the logical encoder: prompt -> KV cache + first
                           logits.  With zero adapters the cache is pure
                           base-model cache (ICaRus mode, shareable across
                           models); with a conventional adapter the cache
                           is model-specific (baseline mode).
  * ``decode_baseline``  — conventional fine-tuned model decode: one
                           stream, adapter on q,k,v,o,mlp, writes *its*
                           cache.
  * ``decode_icarus``    — Algorithm 3: stacked [2,1,d] encoder/decoder
                           streams; the frozen encoder stream writes the
                           shared cache, the adapter stream predicts the
                           task token; paired-query attention reads KV
                           once for both streams.
  * training forwards    — ``forward_conventional`` / ``forward_icarus``
                           full-sequence versions used by ``train.py`` to
                           reproduce the accuracy experiments.

Adapter convention: ``lora`` is a list (one dict per layer) mapping target
name in {q,k,v,o,gate,up,down} to an ``(A, B)`` pair.  ICaRus never reads
the k/v entries (the logical encoder is frozen); they exist so the two
modes share one artifact signature and are zero-enforced by training.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from .kernels.icarus_attention import paired_decode_attention
from .kernels.icarus_linear import icarus_linear
from .kernels.prefill_attention import prefill_attention
from .kernels import ref as kref

Params = Dict[str, Any]
Lora = List[Dict[str, Tuple[jnp.ndarray, jnp.ndarray]]]

LORA_TARGETS = ("q", "k", "v", "o", "gate", "up", "down")
# Targets the ICaRus logical decoder may adapt (k/v belong to the frozen
# logical encoder).
ICARUS_TARGETS = ("q", "o", "gate", "up", "down")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture hyperparameters of one model size."""

    name: str
    vocab: int
    d_model: int
    layers: int
    heads: int
    kv_heads: int
    head_dim: int
    ffn: int
    max_seq: int
    lora_rank: int = 8
    lora_alpha: float = 16.0
    rope_theta: float = 10000.0

    @property
    def q_dim(self) -> int:
        return self.heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.kv_heads * self.head_dim

    @property
    def lora_scale(self) -> float:
        return self.lora_alpha / self.lora_rank

    def param_count(self) -> int:
        per_layer = (
            self.d_model * self.q_dim          # wq
            + 2 * self.d_model * self.kv_dim   # wk, wv
            + self.q_dim * self.d_model        # wo
            + 2 * self.d_model * self.ffn      # gate, up
            + self.ffn * self.d_model          # down
            + 2 * self.d_model                 # norms
        )
        return (
            self.vocab * self.d_model * 2      # embed + lm head
            + self.layers * per_layer
            + self.d_model
        )

    def kv_bytes_per_token(self) -> int:
        """f32 KV cache bytes per token — used by the L3 block allocator."""
        return self.layers * 2 * self.kv_dim * 4


# Serving configs (AOT-compiled to artifacts).  Sizes are the paper's
# LLaMA-8B / Qwen-14B stand-ins (see README.md §Substitutions).
SERVE_SMALL = ModelConfig(
    name="serve-small", vocab=2048, d_model=128, layers=4, heads=8,
    kv_heads=4, head_dim=16, ffn=352, max_seq=1024,
)
SERVE_BASE = ModelConfig(
    name="serve-base", vocab=4096, d_model=256, layers=8, heads=8,
    kv_heads=4, head_dim=32, ffn=704, max_seq=1024,
)
# Training configs (accuracy experiments; never AOT-compiled).
TRAIN_TINY = ModelConfig(
    name="train-tiny", vocab=256, d_model=64, layers=2, heads=4,
    kv_heads=2, head_dim=16, ffn=176, max_seq=64,
)
TRAIN_SMALL = ModelConfig(
    name="train-small", vocab=256, d_model=96, layers=3, heads=6,
    kv_heads=2, head_dim=16, ffn=256, max_seq=64,
)
TRAIN_BASE = ModelConfig(
    name="train-base", vocab=256, d_model=128, layers=4, heads=8,
    kv_heads=4, head_dim=16, ffn=352, max_seq=64,
)

CONFIGS = {
    c.name: c
    for c in (SERVE_SMALL, SERVE_BASE, TRAIN_TINY, TRAIN_SMALL, TRAIN_BASE)
}


# --------------------------------------------------------------------------
# Parameter initialization
# --------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    """Random base-model parameters (stands in for the pretrained LLM)."""
    keys = jax.random.split(key, 2 + cfg.layers)

    def dense(k, shape):
        fan_in = shape[0]
        return jax.random.normal(k, shape, jnp.float32) / jnp.sqrt(fan_in)

    layers = []
    for i in range(cfg.layers):
        lk = jax.random.split(keys[2 + i], 7)
        layers.append({
            "attn_norm": jnp.ones((cfg.d_model,), jnp.float32),
            "wq": dense(lk[0], (cfg.d_model, cfg.q_dim)),
            "wk": dense(lk[1], (cfg.d_model, cfg.kv_dim)),
            "wv": dense(lk[2], (cfg.d_model, cfg.kv_dim)),
            "wo": dense(lk[3], (cfg.q_dim, cfg.d_model)),
            "mlp_norm": jnp.ones((cfg.d_model,), jnp.float32),
            "w_gate": dense(lk[4], (cfg.d_model, cfg.ffn)),
            "w_up": dense(lk[5], (cfg.d_model, cfg.ffn)),
            "w_down": dense(lk[6], (cfg.ffn, cfg.d_model)),
        })
    return {
        "embed": jax.random.normal(keys[0], (cfg.vocab, cfg.d_model)) * 0.02,
        "layers": layers,
        "norm": jnp.ones((cfg.d_model,), jnp.float32),
        "lm_head": dense(keys[1], (cfg.d_model, cfg.vocab)),
    }


def init_lora(cfg: ModelConfig, key: jax.Array, targets=LORA_TARGETS,
              zero: bool = False) -> Lora:
    """LoRA factors.  B starts at zero (standard), A random normal."""
    dims = {
        "q": (cfg.d_model, cfg.q_dim),
        "k": (cfg.d_model, cfg.kv_dim),
        "v": (cfg.d_model, cfg.kv_dim),
        "o": (cfg.q_dim, cfg.d_model),
        "gate": (cfg.d_model, cfg.ffn),
        "up": (cfg.d_model, cfg.ffn),
        "down": (cfg.ffn, cfg.d_model),
    }
    out: Lora = []
    keys = jax.random.split(key, cfg.layers)
    for i in range(cfg.layers):
        tk = jax.random.split(keys[i], len(LORA_TARGETS))
        layer = {}
        for j, t in enumerate(LORA_TARGETS):
            din, dout = dims[t]
            if t in targets and not zero:
                a = jax.random.normal(tk[j], (din, cfg.lora_rank)) / jnp.sqrt(din)
            else:
                a = jnp.zeros((din, cfg.lora_rank), jnp.float32)
            layer[t] = (a, jnp.zeros((cfg.lora_rank, dout), jnp.float32))
        out.append(layer)
    return out


def zero_lora(cfg: ModelConfig) -> Lora:
    return init_lora(cfg, jax.random.PRNGKey(0), targets=(), zero=True)


# --------------------------------------------------------------------------
# Building blocks
# --------------------------------------------------------------------------

def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-5):
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * scale


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float):
    """Rotary embedding.  x: [..., T, n_heads, dh], positions: [T]."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions[:, None].astype(jnp.float32) * freqs[None, :]
    cos = jnp.cos(angles)[:, None, :]  # [T, 1, half]
    sin = jnp.sin(angles)[:, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)


def lora_apply(x, w, ab, scale):
    """Base matmul + LoRA delta (single stream)."""
    a, b = ab
    return x @ w + (x @ a) @ b * scale


def silu(x):
    return x * jax.nn.sigmoid(x)


# --------------------------------------------------------------------------
# Prefill (logical encoder)
# --------------------------------------------------------------------------

def prefill(cfg: ModelConfig, params: Params, lora: Lora,
            tokens: jnp.ndarray, true_len: jnp.ndarray,
            use_kernels: bool = False):
    """Run the prompt through the model, producing KV cache + last logits.

    With ``lora == zero_lora`` this is exactly the frozen logical encoder
    E_base of Eq. 4 and the cache is identical for every ICaRus model.
    Baseline mode passes the model's own adapter (cache becomes
    model-specific, Eq. 2 with task-tuned E).

    Args:
      tokens: i32[S] padded prompt.  true_len: i32[] actual length.

    Returns:
      (k_cache f32[L,S,KV,dh], v_cache f32[L,S,KV,dh], logits f32[V])
      logits are for position ``true_len - 1`` (the next-token logits).
    """
    s = tokens.shape[0]
    scale = cfg.lora_scale
    x = params["embed"][tokens]  # [S, d]
    positions = jnp.arange(s)
    k_cache = []
    v_cache = []
    for li, lp in enumerate(params["layers"]):
        la = lora[li]
        h = rmsnorm(x, lp["attn_norm"])
        q = lora_apply(h, lp["wq"], la["q"], scale)
        k = lora_apply(h, lp["wk"], la["k"], scale)
        v = lora_apply(h, lp["wv"], la["v"], scale)
        q = q.reshape(s, cfg.heads, cfg.head_dim)
        k = k.reshape(s, cfg.kv_heads, cfg.head_dim)
        v = v.reshape(s, cfg.kv_heads, cfg.head_dim)
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
        if use_kernels:
            attn = prefill_attention(q, k, v, true_len, cfg.kv_heads)
        else:
            attn = kref.prefill_attention_ref(q, k, v, true_len, cfg.kv_heads)
        attn = attn.reshape(s, cfg.q_dim)
        x = x + lora_apply(attn, lp["wo"], la["o"], scale)
        h2 = rmsnorm(x, lp["mlp_norm"])
        gate = lora_apply(h2, lp["w_gate"], la["gate"], scale)
        up = lora_apply(h2, lp["w_up"], la["up"], scale)
        x = x + lora_apply(silu(gate) * up, lp["w_down"], la["down"], scale)
        k_cache.append(k)
        v_cache.append(v)
    xl = rmsnorm(x, params["norm"])
    logits = xl[true_len - 1] @ params["lm_head"]
    return jnp.stack(k_cache), jnp.stack(v_cache), logits


# --------------------------------------------------------------------------
# Decode — baseline (conventional fine-tuned model)
# --------------------------------------------------------------------------

def decode_baseline(cfg: ModelConfig, params: Params, lora: Lora,
                    token: jnp.ndarray, pos: jnp.ndarray,
                    k_cache: jnp.ndarray, v_cache: jnp.ndarray):
    """One conventional decode step.

    The adapter touches every projection including k/v, so the cache this
    writes is *model-specific* — the reason baseline multi-model serving
    cannot share caches.

    Args:
      token: i32[] current token.  pos: i32[] its position.
      k_cache/v_cache: f32[L, S, KV, dh] (functional: updated copies are
        returned; the Rust runtime keeps them device-resident).

    Returns:
      (logits f32[V], k_cache', v_cache')
    """
    scale = cfg.lora_scale
    x = params["embed"][token][None, :]  # [1, d]
    pos_arr = jnp.reshape(pos, (1,))
    for li, lp in enumerate(params["layers"]):
        la = lora[li]
        h = rmsnorm(x, lp["attn_norm"])
        q = lora_apply(h, lp["wq"], la["q"], scale)
        k = lora_apply(h, lp["wk"], la["k"], scale)
        v = lora_apply(h, lp["wv"], la["v"], scale)
        q = rope(q.reshape(1, cfg.heads, cfg.head_dim), pos_arr,
                 cfg.rope_theta)
        k = rope(k.reshape(1, cfg.kv_heads, cfg.head_dim), pos_arr,
                 cfg.rope_theta)
        v = v.reshape(1, cfg.kv_heads, cfg.head_dim)
        k_cache = jax.lax.dynamic_update_slice(
            k_cache, k[None], (li, pos, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(
            v_cache, v[None], (li, pos, 0, 0))
        # Single-stream attention == paired attention with q duplicated;
        # reuse the reference to keep one code path.
        q2 = jnp.concatenate([q, q], axis=0)  # [2, H, dh] — wasteful but
        attn = kref.paired_decode_attention_ref(
            q2, k_cache[li], v_cache[li], pos, cfg.kv_heads)[0]
        attn = attn.reshape(1, cfg.q_dim)
        x = x + lora_apply(attn, lp["wo"], la["o"], scale)
        h2 = rmsnorm(x, lp["mlp_norm"])
        gate = lora_apply(h2, lp["w_gate"], la["gate"], scale)
        up = lora_apply(h2, lp["w_up"], la["up"], scale)
        x = x + lora_apply(silu(gate) * up, lp["w_down"], la["down"], scale)
    logits = rmsnorm(x[0], params["norm"]) @ params["lm_head"]
    return logits, k_cache, v_cache


# --------------------------------------------------------------------------
# Decode — ICaRus (Algorithm 3)
# --------------------------------------------------------------------------

def decode_icarus(cfg: ModelConfig, params: Params, lora: Lora,
                  token: jnp.ndarray, pos: jnp.ndarray,
                  k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                  use_kernels: bool = True):
    """One ICaRus decode step (paper Algorithm 3).

    Stream 0 is the frozen logical encoder: it computes this step's k/v
    (written to the shared cache) and propagates the pure base hidden
    state.  Stream 1 is the logical decoder: base + adapter, produces the
    task-specific logits.  Both streams run as one stacked [2,1,d] batch
    so base weights and KV cache are read once (ICaRusLinear + paired-
    query attention kernels).

    Returns:
      (logits f32[V], k_cache', v_cache') — the returned cache is pure
      base-model cache, reusable by every other ICaRus model.
    """
    scale = cfg.lora_scale
    emb = params["embed"][token][None, :]
    x = jnp.stack([emb, emb])  # [2, 1, d]
    pos_arr = jnp.reshape(pos, (1,))
    for li, lp in enumerate(params["layers"]):
        la = lora[li]
        h = rmsnorm(x, lp["attn_norm"])  # [2, 1, d]
        if use_kernels:
            q_pair = icarus_linear(h, lp["wq"], la["q"][0], la["q"][1], scale)
        else:
            q_pair = kref.icarus_linear_ref(
                h, lp["wq"], la["q"][0], la["q"][1], scale)
        # k/v from the encoder stream only, base weights only (Alg. 3 l.7).
        k = h[0] @ lp["wk"]
        v = h[0] @ lp["wv"]
        q_pair = _rope_pair(cfg, q_pair, pos_arr)
        k = rope(k.reshape(1, cfg.kv_heads, cfg.head_dim), pos_arr,
                 cfg.rope_theta)
        v = v.reshape(1, cfg.kv_heads, cfg.head_dim)
        k_cache = jax.lax.dynamic_update_slice(
            k_cache, k[None], (li, pos, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(
            v_cache, v[None], (li, pos, 0, 0))
        if use_kernels:
            attn = paired_decode_attention(
                q_pair, k_cache[li], v_cache[li], pos, cfg.kv_heads)
        else:
            attn = kref.paired_decode_attention_ref(
                q_pair, k_cache[li], v_cache[li], pos, cfg.kv_heads)
        attn = attn.reshape(2, 1, cfg.q_dim)
        if use_kernels:
            z = icarus_linear(attn, lp["wo"], la["o"][0], la["o"][1], scale)
            x = x + z
            h2 = rmsnorm(x, lp["mlp_norm"])
            gate = icarus_linear(
                h2, lp["w_gate"], la["gate"][0], la["gate"][1], scale)
            up = icarus_linear(h2, lp["w_up"], la["up"][0], la["up"][1], scale)
            act = silu(gate) * up
            x = x + icarus_linear(
                act, lp["w_down"], la["down"][0], la["down"][1], scale)
        else:
            z = kref.icarus_linear_ref(
                attn, lp["wo"], la["o"][0], la["o"][1], scale)
            x = x + z
            h2 = rmsnorm(x, lp["mlp_norm"])
            gate = kref.icarus_linear_ref(
                h2, lp["w_gate"], la["gate"][0], la["gate"][1], scale)
            up = kref.icarus_linear_ref(
                h2, lp["w_up"], la["up"][0], la["up"][1], scale)
            act = silu(gate) * up
            x = x + kref.icarus_linear_ref(
                act, lp["w_down"], la["down"][0], la["down"][1], scale)
    # Only the adapter stream's output is sampled (Alg. 3 l.20).
    logits = rmsnorm(x[1, 0], params["norm"]) @ params["lm_head"]
    return logits, k_cache, v_cache


def _rope_pair(cfg: ModelConfig, q_pair: jnp.ndarray, pos_arr: jnp.ndarray):
    """RoPE over the stacked [2, 1, H*dh] query pair -> [2, H, dh]."""
    q = q_pair.reshape(2, cfg.heads, cfg.head_dim)
    # rope expects [T, heads, dh]; treat the stream axis as T with equal
    # positions for both streams.
    pos2 = jnp.concatenate([pos_arr, pos_arr])
    return rope(q, pos2, cfg.rope_theta)


# --------------------------------------------------------------------------
# Full-sequence training forwards (used by train.py, never AOT-compiled)
# --------------------------------------------------------------------------

def forward_conventional(cfg: ModelConfig, params: Params, lora: Lora,
                         tokens: jnp.ndarray) -> jnp.ndarray:
    """Standard causal forward with LoRA on all targets.  tokens: i32[B,S].

    Returns logits f32[B,S,V].
    """
    b, s = tokens.shape
    scale = cfg.lora_scale
    x = params["embed"][tokens]
    positions = jnp.arange(s)
    causal = positions[:, None] >= positions[None, :]
    for li, lp in enumerate(params["layers"]):
        la = lora[li]
        h = rmsnorm(x, lp["attn_norm"])
        q = lora_apply(h, lp["wq"], la["q"], scale)
        k = lora_apply(h, lp["wk"], la["k"], scale)
        v = lora_apply(h, lp["wv"], la["v"], scale)
        attn = _gqa_full(cfg, q, k, v, positions, causal)
        x = x + lora_apply(attn, lp["wo"], la["o"], scale)
        h2 = rmsnorm(x, lp["mlp_norm"])
        gate = lora_apply(h2, lp["w_gate"], la["gate"], scale)
        up = lora_apply(h2, lp["w_up"], la["up"], scale)
        x = x + lora_apply(silu(gate) * up, lp["w_down"], la["down"], scale)
    return rmsnorm(x, params["norm"]) @ params["lm_head"]


def forward_icarus(cfg: ModelConfig, params: Params, lora: Lora,
                   tokens: jnp.ndarray) -> jnp.ndarray:
    """ICaRus training forward (paper §3.2).

    The input is duplicated: the frozen encoder stream runs the pure base
    model and provides K/V for every position; the decoder stream (base +
    adapter on q,o,mlp) attends to the encoder's K/V and produces the
    logits the loss is computed on.

    Returns decoder logits f32[B,S,V].
    """
    b, s = tokens.shape
    scale = cfg.lora_scale
    e = params["embed"][tokens]   # encoder stream (frozen base)
    d = e                         # decoder stream (base + adapter)
    positions = jnp.arange(s)
    causal = positions[:, None] >= positions[None, :]
    for li, lp in enumerate(params["layers"]):
        la = lora[li]
        he = rmsnorm(e, lp["attn_norm"])
        hd = rmsnorm(d, lp["attn_norm"])
        # Encoder stream: pure base attention over its own K/V.
        qe = he @ lp["wq"]
        k = he @ lp["wk"]
        v = he @ lp["wv"]
        attn_e = _gqa_full(cfg, qe, k, v, positions, causal)
        e2 = e + attn_e @ lp["wo"]
        h2e = rmsnorm(e2, lp["mlp_norm"])
        e = e2 + (silu(h2e @ lp["w_gate"]) * (h2e @ lp["w_up"])) @ lp["w_down"]
        # Decoder stream: adapted q against the *encoder's* K/V.
        qd = lora_apply(hd, lp["wq"], la["q"], scale)
        attn_d = _gqa_full(cfg, qd, k, v, positions, causal)
        d2 = d + lora_apply(attn_d, lp["wo"], la["o"], scale)
        h2d = rmsnorm(d2, lp["mlp_norm"])
        gate = lora_apply(h2d, lp["w_gate"], la["gate"], scale)
        up = lora_apply(h2d, lp["w_up"], la["up"], scale)
        d = d2 + lora_apply(silu(gate) * up, lp["w_down"], la["down"], scale)
    return rmsnorm(d, params["norm"]) @ params["lm_head"]


def forward_base(cfg: ModelConfig, params: Params,
                 tokens: jnp.ndarray) -> jnp.ndarray:
    """Pure base-model forward (pretraining / base-model evals)."""
    return forward_conventional(cfg, params, zero_lora(cfg), tokens)


def _gqa_full(cfg: ModelConfig, q, k, v, positions, causal):
    """Batched full-sequence GQA attention.  q: [B,S,H*dh] etc."""
    b, s = q.shape[:2]
    group = cfg.heads // cfg.kv_heads
    q = _rope_bshd(cfg, q.reshape(b, s, cfg.heads, cfg.head_dim), positions)
    k = _rope_bshd(cfg, k.reshape(b, s, cfg.kv_heads, cfg.head_dim), positions)
    v = v.reshape(b, s, cfg.kv_heads, cfg.head_dim)
    qg = q.reshape(b, s, cfg.kv_heads, group, cfg.head_dim)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k)
    scores = scores / jnp.sqrt(jnp.asarray(cfg.head_dim, jnp.float32))
    scores = jnp.where(causal[None, None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", p, v)
    return out.reshape(b, s, cfg.q_dim)


def _rope_bshd(cfg: ModelConfig, x, positions):
    """RoPE over [B, S, heads, dh]."""
    b, s, h, dh = x.shape
    x2 = x.transpose(1, 0, 2, 3).reshape(s, b * h, dh)
    x2 = rope(x2, positions, cfg.rope_theta)
    return x2.reshape(s, b, h, dh).transpose(1, 0, 2, 3)


def cross_entropy(logits: jnp.ndarray, targets: jnp.ndarray,
                  mask: jnp.ndarray) -> jnp.ndarray:
    """Masked mean next-token cross-entropy.  logits [B,S,V] vs targets
    [B,S]; mask [B,S] selects supervised positions."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
