"""Pallas kernel: paired-query GQA decode attention (Algorithm 3, l.13-16).

The heart of ICaRus's decode phase: the logical-encoder query and the
logical-decoder query are concatenated **along the head dimension** so a
single pass over the shared KV cache serves both streams.  KV-cache read
amplification vs a single model is 1.0 — this is what restores decode
latency to O(M + L_t) memory traffic (Table 1) despite running 2× compute.

TPU mapping: grid = (kv_heads, S/block_s).  Each program streams one
``block_s`` tile of K and V for one KV head through VMEM and updates a
flash-attention style online softmax accumulator in scratch for the
2*group concatenated query heads.  BlockSpec expresses the HBM→VMEM KV
schedule the paper implements with CUDA threadblocks.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
            *, block_s: int, dh: int):
    # Grid: (kv_head k, seq block j). q_ref: [2G, dh] for this kv head;
    # k_ref/v_ref: [block_s, dh]; o_ref: [2G, dh].
    j = pl.program_id(1)
    num_j = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)

    pos = pos_ref[0]
    q = q_ref[...]  # [2G, dh]
    k = k_ref[...]  # [bs, dh]
    v = v_ref[...]
    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) / jnp.sqrt(jnp.asarray(dh, jnp.float32))  # [2G, bs]
    idx = j * block_s + jnp.arange(block_s)
    scores = jnp.where(idx[None, :] <= pos, scores, -1e30)

    # Online softmax update.
    m_prev = m_ref[...]              # [2G]
    m_cur = jnp.maximum(m_prev, scores.max(axis=-1))
    alpha = jnp.exp(m_prev - m_cur)  # rescale of previous accumulator
    p = jnp.exp(scores - m_cur[:, None])   # [2G, bs]
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_cur

    @pl.when(j == num_j - 1)
    def _finish():
        o_ref[...] = acc_ref[...] / l_ref[...][:, None]


def paired_decode_attention(q, k_cache, v_cache, pos, kv_heads, *,
                            block_s: int = 128, interpret: bool = True):
    """Attention for both logical streams with one KV-cache read.

    Args:
      q: f32[2, H, dh] RoPE'd queries (stream 0 = encoder, 1 = decoder).
      k_cache: f32[S, KV, dh]; entry at ``pos`` must already be written.
      v_cache: f32[S, KV, dh].
      pos: i32[] current position (positions > pos are masked out).
      kv_heads: static int, number of KV heads.

    Returns:
      f32[2, H, dh]
    """
    two, h, dh = q.shape
    s = k_cache.shape[0]
    group = h // kv_heads
    bs = min(block_s, s)
    assert s % bs == 0, (s, bs)
    # [2, KV, G, dh] -> [KV, 2G, dh]: the head-dim concat of Alg. 3.
    qg = q.reshape(two, kv_heads, group, dh).transpose(1, 0, 2, 3)
    qg = qg.reshape(kv_heads, two * group, dh)
    kk = k_cache.transpose(1, 0, 2)  # [KV, S, dh]
    vv = v_cache.transpose(1, 0, 2)
    pos_arr = jnp.reshape(pos, (1,)).astype(jnp.int32)

    out = pl.pallas_call(
        functools.partial(_kernel, block_s=bs, dh=dh),
        grid=(kv_heads, s // bs),
        in_specs=[
            pl.BlockSpec((1,), lambda k, j: (0,)),
            pl.BlockSpec((None, two * group, dh), lambda k, j: (k, 0, 0)),
            pl.BlockSpec((None, bs, dh), lambda k, j: (k, j, 0)),
            pl.BlockSpec((None, bs, dh), lambda k, j: (k, j, 0)),
        ],
        out_specs=pl.BlockSpec((None, two * group, dh), lambda k, j: (k, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((kv_heads, two * group, dh),
                                       jnp.float32),
        scratch_shapes=[
            pl.MemorySpace.ANY((two * group, dh), jnp.float32),
            pl.MemorySpace.ANY((two * group,), jnp.float32),
            pl.MemorySpace.ANY((two * group,), jnp.float32),
        ],
        interpret=interpret,
    )(pos_arr, qg, kk, vv)
    out = out.reshape(kv_heads, two, group, dh).transpose(1, 0, 2, 3)
    return out.reshape(two, h, dh)
