"""Pallas kernel: causal GQA prefill attention (logical-encoder pass).

Flash-attention style: grid = (kv_heads, Sq/block_q, Sk/block_k); each
program streams one K/V tile through VMEM and updates an online-softmax
accumulator for one query tile of one KV head group.  Padding positions
(>= ``true_len``) and acausal positions are masked.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
            *, block_q: int, block_k: int, dh: int):
    # Grid: (kv head, q block i, k block j).
    i = pl.program_id(1)
    j = pl.program_id(2)
    num_j = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)

    true_len = len_ref[0]
    q = q_ref[...]  # [bq*G, dh]  (q heads of this kv group, flattened)
    k = k_ref[...]  # [bk, dh]
    v = v_ref[...]
    g = q.shape[0] // block_q
    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) / jnp.sqrt(jnp.asarray(dh, jnp.float32))  # [bq*G, bk]
    q_idx = i * block_q + jnp.arange(block_q)
    k_idx = j * block_k + jnp.arange(block_k)
    q_idx = jnp.repeat(q_idx, g)  # row r belongs to query position r//G
    mask = (q_idx[:, None] >= k_idx[None, :]) & (k_idx[None, :] < true_len)
    scores = jnp.where(mask, scores, -1e30)

    m_prev = m_ref[...]
    m_cur = jnp.maximum(m_prev, scores.max(axis=-1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(scores - m_cur[:, None])
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_cur

    @pl.when(j == num_j - 1)
    def _finish():
        # Fully-masked rows (padding queries) have l == 0; emit zeros.
        l = l_ref[...]
        safe = jnp.where(l > 0.0, l, 1.0)
        o_ref[...] = acc_ref[...] / safe[:, None]


def prefill_attention(q, k, v, true_len, kv_heads, *, block_q: int = 64,
                      block_k: int = 64, interpret: bool = True):
    """Causal GQA attention over a padded prompt.

    Args:
      q: f32[S, H, dh] RoPE'd queries.
      k: f32[S, KV, dh] keys.  v: f32[S, KV, dh] values.
      true_len: i32[] true prompt length; keys beyond it are padding.
      kv_heads: static int.

    Returns:
      f32[S, H, dh] (rows >= true_len are zeros).
    """
    s, h, dh = q.shape
    group = h // kv_heads
    bq = min(block_q, s)
    bk = min(block_k, s)
    assert s % bq == 0 and s % bk == 0, (s, bq, bk)
    # [S, KV, G, dh] -> [KV, S*G, dh]: rows grouped by query position so a
    # q block covers positions [i*bq, (i+1)*bq) for all its group heads.
    qg = q.reshape(s, kv_heads, group, dh).transpose(1, 0, 2, 3)
    qg = qg.reshape(kv_heads, s * group, dh)
    kk = k.transpose(1, 0, 2)
    vv = v.transpose(1, 0, 2)
    len_arr = jnp.reshape(true_len, (1,)).astype(jnp.int32)

    out = pl.pallas_call(
        functools.partial(_kernel, block_q=bq, block_k=bk, dh=dh),
        grid=(kv_heads, s // bq, s // bk),
        in_specs=[
            pl.BlockSpec((1,), lambda kh, i, j: (0,)),
            pl.BlockSpec((None, bq * group, dh), lambda kh, i, j: (kh, i, 0)),
            pl.BlockSpec((None, bk, dh), lambda kh, i, j: (kh, j, 0)),
            pl.BlockSpec((None, bk, dh), lambda kh, i, j: (kh, j, 0)),
        ],
        out_specs=pl.BlockSpec(
            (None, bq * group, dh), lambda kh, i, j: (kh, i, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((kv_heads, s * group, dh), jnp.float32),
        scratch_shapes=[
            pl.MemorySpace.ANY((bq * group, dh), jnp.float32),
            pl.MemorySpace.ANY((bq * group,), jnp.float32),
            pl.MemorySpace.ANY((bq * group,), jnp.float32),
        ],
        interpret=interpret,
    )(len_arr, qg, kk, vv)
    out = out.reshape(kv_heads, s, group, dh).transpose(1, 0, 2, 3)
    return out.reshape(s, h, dh)
