"""Pallas kernel: fused base + LoRA linear (Algorithm 2, "ICaRus Linear").

The paper's decode-phase optimization: the logical encoder (stream 0) and
logical decoder (stream 1) share every base weight matrix, so the weight
is streamed through VMEM **once** per output block and applied to the
stacked [2, T, d_in] activation as a single batched matmul (MXU-friendly).
Only the decoder stream receives the low-rank adapter delta.

TPU mapping (see README.md §Substitutions): the grid walks d_out in
``block_n`` tiles; each program holds one W tile + the full A/B adapter in
VMEM. Weight-read amplification vs a single model is exactly 1.0 — the
paper's memory-traffic claim. Runs under ``interpret=True`` on CPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, a_ref, b_ref, o_ref, *, scale):
    # x_ref: [2, T, d_in] (whole), w_ref: [d_in, bn] tile,
    # a_ref: [d_in, r] (whole), b_ref: [r, bn] tile, o_ref: [2, T, bn].
    x = x_ref[...]
    w = w_ref[...]
    # Shared base matmul: one weight read serves both streams.
    y = jax.lax.dot_general(
        x, w, (((2,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    # LoRA delta on the decoder stream only.
    xa = x[1] @ a_ref[...]
    delta = (xa @ b_ref[...]) * scale
    o_ref[...] = y.at[1].add(delta)


def icarus_linear(x, w, a, b, scale, *, block_n: int = 128,
                  interpret: bool = True):
    """Compute ``[x0 @ w, x1 @ w + (x1 @ a) @ b * scale]``.

    Args:
      x: f32[2, T, d_in] stacked encoder/decoder activations.
      w: f32[d_in, d_out] frozen base weight.
      a: f32[d_in, r], b: f32[r, d_out]: LoRA factors.
      scale: float, LoRA alpha/rank.
      block_n: d_out tile width (VMEM sizing knob).

    Returns:
      f32[2, T, d_out]
    """
    two, t, d_in = x.shape
    d_out = w.shape[1]
    bn = min(block_n, d_out)
    while d_out % bn != 0:  # largest divisor of d_out not above block_n
        bn -= 1
    grid = (d_out // bn,)
    return pl.pallas_call(
        functools.partial(_kernel, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((two, t, d_in), lambda j: (0, 0, 0)),
            pl.BlockSpec((d_in, bn), lambda j: (0, j)),
            pl.BlockSpec(a.shape, lambda j: (0, 0)),
            pl.BlockSpec((b.shape[0], bn), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((two, t, bn), lambda j: (0, 0, j)),
        out_shape=jax.ShapeDtypeStruct((two, t, d_out), jnp.float32),
        interpret=interpret,
    )(x, w, a, b)
