"""Pure-jnp reference oracles for the ICaRus Pallas kernels.

These are the ground truth that every Pallas kernel is checked against in
``python/tests/``; they are also selectable as the lowering path for the
AOT artifacts (``aot.py --kernels ref``) since they are mathematically
identical to the kernels.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def icarus_linear_ref(x, w, a, b, scale):
    """Fused base + LoRA linear over a [2, T, d_in] stacked activation.

    Stream 0 (logical encoder) sees only the frozen base weight ``w``;
    stream 1 (logical decoder) additionally receives the LoRA delta
    ``(x[1] @ a) @ b * scale``.  This is Algorithm 2 (``ICaRus Linear``)
    of the paper: the base matmul is shared so the weight matrix is read
    once for both streams.

    Args:
      x: f32[2, T, d_in] stacked encoder/decoder activations.
      w: f32[d_in, d_out] frozen base weight.
      a: f32[d_in, r] LoRA down-projection.
      b: f32[r, d_out] LoRA up-projection.
      scale: python float, LoRA alpha / rank.

    Returns:
      f32[2, T, d_out]
    """
    y = jnp.einsum("btd,df->btf", x, w)
    delta = (x[1] @ a) @ b * scale
    return y.at[1].add(delta)


def paired_decode_attention_ref(q, k_cache, v_cache, pos, kv_heads):
    """Paired-query GQA decode attention over the shared KV cache.

    Algorithm 3 lines 13-16: the logical-encoder and logical-decoder
    queries are concatenated along the head axis so one pass over the
    (shared) KV cache serves both streams.

    Args:
      q: f32[2, H, dh] RoPE'd queries for this decode step
         (stream 0 = encoder, stream 1 = decoder).
      k_cache: f32[S, KV, dh] key cache (entry at ``pos`` already written).
      v_cache: f32[S, KV, dh] value cache.
      pos: i32 scalar, index of the current token; positions > pos masked.
      kv_heads: static int, number of KV heads (GQA groups).

    Returns:
      f32[2, H, dh] attention outputs per stream.
    """
    two, h, dh = q.shape
    s = k_cache.shape[0]
    group = h // kv_heads
    # [2, KV, group, dh] -> [KV, 2*group, dh]: concat along head dim.
    qg = q.reshape(two, kv_heads, group, dh).transpose(1, 0, 2, 3)
    qg = qg.reshape(kv_heads, two * group, dh)
    k = k_cache.transpose(1, 0, 2)  # [KV, S, dh]
    v = v_cache.transpose(1, 0, 2)
    scores = jnp.einsum("kgd,ksd->kgs", qg, k) / jnp.sqrt(
        jnp.asarray(dh, jnp.float32)
    )
    mask = jnp.arange(s)[None, None, :] <= pos
    scores = jnp.where(mask, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("kgs,ksd->kgd", p, v)  # [KV, 2*group, dh]
    out = out.reshape(kv_heads, two, group, dh).transpose(1, 0, 2, 3)
    return out.reshape(two, h, dh)


def prefill_attention_ref(q, k, v, true_len, kv_heads):
    """Causal GQA prefill attention (logical-encoder pass).

    Args:
      q: f32[S, H, dh] RoPE'd queries.
      k: f32[S, KV, dh] keys.
      v: f32[S, KV, dh] values.
      true_len: i32 scalar; keys at position >= true_len are padding.
      kv_heads: static int.

    Returns:
      f32[S, H, dh]
    """
    s, h, dh = q.shape
    group = h // kv_heads
    qg = q.reshape(s, kv_heads, group, dh)
    scores = jnp.einsum("skgd,tkd->kgst", qg, k) / jnp.sqrt(
        jnp.asarray(dh, jnp.float32)
    )
    ar = jnp.arange(s)
    causal = ar[:, None] >= ar[None, :]
    valid = ar[None, :] < true_len
    mask = (causal & valid)[None, None, :, :]
    scores = jnp.where(mask, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("kgst,tkd->skgd", p, v)
    return out.reshape(s, h, dh)
