"""Synthetic task families for the accuracy experiments.

Substitutes for the paper's fine-tuning datasets (README.md §Substitutions
table): each family produces supervised (tokens, loss_mask) sequences and
an exact-match evaluator, so we can reproduce the *comparison structure*
of Tables 2-5: base model weak everywhere, task-specialists strong on
their own task, conventional-LoRA vs ICaRus-LoRA head to head.

  math   (MetaMathQA stand-in)  — modular arithmetic, multi-digit.
  code   (Evol-Instruct-Code)   — bracket-language auto-closing.
  know   (OASST1 / GPQA)        — two-hop key-value knowledge recall.
  tool   (ToolACE / BFCL)       — function-call formatting.

Evals: ``{task}`` is in-distribution, ``{task}_plus`` is the harder
variant (more operands / deeper nesting / second hop), mirroring
GSM8K vs GSM-Plus and HumanEval vs HumanEval+.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Tuple

import numpy as np

# Token map (vocab 256, shared by all training configs).
PAD, BOS, EOS, SEP, ANS = 0, 1, 2, 3, 4
TAG_MATH, TAG_CODE, TAG_KNOW, TAG_TOOL = 5, 6, 7, 8
OP_ADD, OP_SUB, OP_MUL, EQ = 9, 10, 11, 12
OPEN_A, CLOSE_A, OPEN_B, CLOSE_B = 13, 14, 15, 16
CALL, LPAR, RPAR, COMMA = 17, 18, 19, 20
DIGIT0 = 30          # digits 30..39
ENTITY0 = 40         # entities 40..103 (64)
ATTR0 = 104          # attribute names 104..111 (8)
VALUE0 = 112         # attribute values 112..175 (64)
FUNC0 = 176          # function ids 176..191 (16)
ARG0 = 192           # argument tokens 192..255 (64)

N_ENTITY, N_ATTR, N_VALUE, N_FUNC, N_ARG = 64, 8, 64, 16, 64

MOD = 10  # single-digit modular arithmetic (learnable at tiny scale)


@dataclasses.dataclass
class Example:
    tokens: np.ndarray   # i32[S]
    mask: np.ndarray     # f32[S] — 1.0 on supervised (answer) positions
    prompt_len: int      # answer begins at this index
    answer: List[int]


def _digits(n: int, width: int = 2) -> List[int]:
    """Zero-padded fixed-width digits — removes length ambiguity so the
    exact-match evaluator measures arithmetic, not length prediction."""
    return [DIGIT0 + int(c) for c in str(n).zfill(width)]


def _pad(tokens: List[int], mask: List[float], seq: int) -> Example:
    assert len(tokens) <= seq, (len(tokens), seq)
    t = np.full(seq, PAD, np.int32)
    m = np.zeros(seq, np.float32)
    t[: len(tokens)] = tokens
    m[: len(mask)] = mask
    ans_start = next(i for i, v in enumerate(mask) if v > 0)
    answer = tokens[ans_start:]
    return Example(t, m, ans_start, answer)


def _wrap(prompt: List[int], answer: List[int], seq: int) -> Example:
    tokens = prompt + answer + [EOS]
    mask = [0.0] * len(prompt) + [1.0] * (len(answer) + 1)
    return _pad(tokens, mask, seq)


# --------------------------------------------------------------------------
# Task generators.  ``hard=True`` is the "_plus" eval variant.
# --------------------------------------------------------------------------

def gen_math(rng: np.random.Generator, seq: int, hard: bool = False) -> Example:
    easy_ops = [(OP_ADD, lambda a, b: a + b), (OP_SUB, lambda a, b: a - b)]
    all_ops = easy_ops + [(OP_MUL, lambda a, b: a * b)]
    if hard:
        # Three operands, two ops (incl. mul): compositional, GSM-Plus-ish.
        a, b, c = (int(rng.integers(0, MOD)) for _ in range(3))
        (o1, f1), (o2, f2) = (all_ops[int(rng.integers(3))] for _ in range(2))
        val = f2(f1(a, b), c) % MOD
        prompt = ([BOS, TAG_MATH] + _digits(a, 1) + [o1] + _digits(b, 1)
                  + [o2] + _digits(c, 1) + [EQ])
    else:
        a, b = int(rng.integers(0, MOD)), int(rng.integers(0, MOD))
        o, f = easy_ops[int(rng.integers(2))]
        val = f(a, b) % MOD
        prompt = [BOS, TAG_MATH] + _digits(a, 1) + [o] + _digits(b, 1) + [EQ]
    return _wrap(prompt, _digits(val, 1), seq)


def gen_code(rng: np.random.Generator, seq: int, hard: bool = False) -> Example:
    """Auto-close a random well-prefixed bracket string (stack discipline)."""
    depth_cap = 6 if hard else 3
    length = int(rng.integers(4, 12 if hard else 8))
    pairs = [(OPEN_A, CLOSE_A), (OPEN_B, CLOSE_B)]
    stack: List[int] = []
    body: List[int] = []
    for _ in range(length):
        if stack and (len(stack) >= depth_cap or rng.random() < 0.35):
            body.append(stack.pop())
        else:
            o, c = pairs[int(rng.integers(2))]
            body.append(o)
            stack.append(c)
    answer = list(reversed(stack)) if stack else [SEP]
    prompt = [BOS, TAG_CODE] + body + [ANS]
    return _wrap(prompt, answer, seq)


class KnowledgeBase:
    """Fixed entity->attr->value world, shared by train and eval.

    Values are themselves drawn from the entity token range for half the
    attributes, enabling the two-hop "_plus" queries (GPQA stand-in).
    """

    def __init__(self, seed: int = 7):
        rng = np.random.default_rng(seed)
        self.table = {}
        for e in range(N_ENTITY):
            self.table[e] = {}
            for a in range(N_ATTR):
                if a < N_ATTR // 2:
                    self.table[e][a] = ("value", int(rng.integers(N_VALUE)))
                else:
                    self.table[e][a] = ("entity", int(rng.integers(N_ENTITY)))


KB = KnowledgeBase()


def gen_know(rng: np.random.Generator, seq: int, hard: bool = False) -> Example:
    e = int(rng.integers(N_ENTITY))
    if hard:
        # Two-hop: entity --attr_e--> entity2 --attr_v--> value.
        a1 = int(rng.integers(N_ATTR // 2, N_ATTR))
        _, e2 = KB.table[e][a1]
        a2 = int(rng.integers(N_ATTR // 2))
        _, v = KB.table[e2][a2]
        prompt = [BOS, TAG_KNOW, ENTITY0 + e, ATTR0 + a1, ATTR0 + a2, ANS]
        answer = [VALUE0 + v]
    else:
        a = int(rng.integers(N_ATTR // 2))
        _, v = KB.table[e][a]
        prompt = [BOS, TAG_KNOW, ENTITY0 + e, ATTR0 + a, ANS]
        answer = [VALUE0 + v]
    return _wrap(prompt, answer, seq)


def gen_tool(rng: np.random.Generator, seq: int, hard: bool = False) -> Example:
    """Format a function call: echo the func id and sort its arguments."""
    f = int(rng.integers(N_FUNC))
    n_args = int(rng.integers(3, 6)) if hard else int(rng.integers(1, 4))
    args = rng.choice(N_ARG, size=n_args, replace=False)
    prompt = [BOS, TAG_TOOL, FUNC0 + f] + [ARG0 + int(a) for a in args] + [ANS]
    out = [CALL, FUNC0 + f, LPAR]
    for i, a in enumerate(sorted(int(x) for x in args)):
        if i:
            out.append(COMMA)
        out.append(ARG0 + a)
    out.append(RPAR)
    return _wrap(prompt, out, seq)


GENERATORS: Dict[str, Callable[..., Example]] = {
    "math": gen_math,
    "code": gen_code,
    "know": gen_know,
    "tool": gen_tool,
}

# Eval suites: (task generator, hard flag).  Names mirror the paper's
# benchmarks (see module docstring).
EVALS: Dict[str, Tuple[str, bool]] = {
    "gsm8k": ("math", False),
    "gsm_plus": ("math", True),
    "heval": ("code", False),
    "heval_plus": ("code", True),
    "gpqa": ("know", True),
    "know": ("know", False),
    "bfcl": ("tool", False),
    "bfcl_plus": ("tool", True),
}


def batch(task: str, rng: np.random.Generator, n: int, seq: int,
          hard: bool = False):
    """Generate a batch: (tokens i32[n,seq], mask f32[n,seq], examples)."""
    exs = [GENERATORS[task](rng, seq, hard) for _ in range(n)]
    toks = np.stack([e.tokens for e in exs])
    mask = np.stack([e.mask for e in exs])
    return toks, mask, exs


def mixture_batch(rng: np.random.Generator, n: int, seq: int,
                  tasks=("math", "code", "know", "tool")):
    """Mixed-task batch used to pretrain the base model."""
    exs = [GENERATORS[tasks[int(rng.integers(len(tasks)))]](rng, seq)
           for _ in range(n)]
    toks = np.stack([e.tokens for e in exs])
    mask = np.stack([e.mask for e in exs])
    return toks, mask, exs
