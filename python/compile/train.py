"""Accuracy-experiment reproduction: conventional LoRA vs ICaRus.

Reproduces (on the synthetic substitutes of README.md §Substitutions):
  * Fig 2 / Fig 7 — training-loss curves of conventional fine-tuning vs
    ICaRus nearly overlap.
  * Table 2       — ICaRus accuracy ≈ task-specific fine-tuning across
    math / coding / knowledge, two model sizes.
  * Table 3       — scaling across model sizes (math task).
  * Table 4       — specialist cross-eval matrix: single specialists vs
    multi-model vs ICaRus.
  * Table 5       — tool-calling task on the largest training config.

Pipeline: "pretrain" a base model (under-trained on a task mixture — the
pretrained-LLM stand-in), then fine-tune per-task adapters two ways:
conventional (LoRA on q,k,v,o,mlp — the logical encoder moves, caches are
model-specific) and ICaRus (LoRA on q,o,mlp via ``forward_icarus`` — the
logical encoder stays frozen, caches shared).

Usage:  cd python && python -m compile.train --exp all --out-dir ../experiments
"""

from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M
from . import tasks as T


# --------------------------------------------------------------------------
# Minimal Adam over a pytree (no optax offline)
# --------------------------------------------------------------------------

def adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params),
            "t": jnp.zeros((), jnp.int32)}


def adam_update(grads, state, params, lr, b1=0.9, b2=0.999, eps=1e-8,
                weight_decay=0.01):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(
        lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(
        lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    mh_scale = 1.0 / (1 - b1 ** t.astype(jnp.float32))
    vh_scale = 1.0 / (1 - b2 ** t.astype(jnp.float32))
    new_params = jax.tree_util.tree_map(
        lambda p, m_, v_: p - lr * (
            m_ * mh_scale / (jnp.sqrt(v_ * vh_scale) + eps)
            + weight_decay * p),
        params, m, v)
    return new_params, {"m": m, "v": v, "t": t}


# --------------------------------------------------------------------------
# Pretraining the base model (the "pretrained LLM" stand-in)
# --------------------------------------------------------------------------

def pretrain_base(cfg: M.ModelConfig, steps: int, batch_size: int, seq: int,
                  seed: int = 0, lr: float = 3e-3):
    """Under-train the base on the task mixture: competent at the formats,
    weak at the answers — like a pretrained LLM before task fine-tuning."""
    params = M.init_params(cfg, jax.random.PRNGKey(seed))
    zl = M.zero_lora(cfg)
    opt = adam_init(params)
    rng = np.random.default_rng(seed + 1)

    def loss_fn(p, toks, mask):
        logits = M.forward_conventional(cfg, p, zl, toks)
        return M.cross_entropy(logits[:, :-1], toks[:, 1:], mask[:, 1:])

    step = jax.jit(lambda p, o, toks, mask: _step(loss_fn, p, o, toks, mask, lr))
    losses = []
    for i in range(steps):
        toks, mask, _ = T.mixture_batch(rng, batch_size, seq)
        params, opt, loss = step(params, opt, jnp.asarray(toks),
                                 jnp.asarray(mask))
        losses.append(float(loss))
    return params, losses


def _step(loss_fn, params, opt, toks, mask, lr):
    loss, grads = jax.value_and_grad(loss_fn)(params, toks, mask)
    params, opt = adam_update(grads, opt, params, lr)
    return params, opt, loss


# --------------------------------------------------------------------------
# LoRA fine-tuning (both methods)
# --------------------------------------------------------------------------

def finetune(cfg: M.ModelConfig, params: M.Params, task: str, method: str,
             steps: int, batch_size: int, seq: int, seed: int = 0,
             lr: float = 1e-3):
    """Fine-tune one task adapter.  method in {conventional, icarus}.

    Returns (lora, loss_curve).  Only LoRA params receive gradients; in
    ICaRus mode the k/v adapters additionally stay zero (frozen logical
    encoder) and the forward is ``forward_icarus``.
    """
    targets = M.LORA_TARGETS if method == "conventional" else M.ICARUS_TARGETS
    lora = M.init_lora(cfg, jax.random.PRNGKey(seed + 100), targets=targets)
    fwd = (M.forward_conventional if method == "conventional"
           else M.forward_icarus)
    opt = adam_init(lora)
    rng = np.random.default_rng(seed + 2)
    # Mask of trainable leaves: zero out grads for non-target adapters so
    # e.g. ICaRus never updates k/v (the logical encoder stays frozen).
    train_mask = [
        {t: (jnp.float32(t in targets), jnp.float32(t in targets))
         for t in M.LORA_TARGETS}
        for _ in range(cfg.layers)
    ]

    def loss_fn(lo, toks, mask):
        logits = fwd(cfg, params, lo, toks)
        return M.cross_entropy(logits[:, :-1], toks[:, 1:], mask[:, 1:])

    @jax.jit
    def step(lo, o, toks, mask):
        loss, grads = jax.value_and_grad(loss_fn)(lo, toks, mask)
        grads = jax.tree_util.tree_map(lambda g, m: g * m, grads, train_mask)
        lo, o = adam_update(grads, o, lo, lr)
        return lo, o, loss

    losses = []
    for i in range(steps):
        toks, mask, _ = T.batch(task, rng, batch_size, seq)
        lora, opt, loss = step(lora, opt, jnp.asarray(toks),
                               jnp.asarray(mask))
        losses.append(float(loss))
    return lora, losses


# --------------------------------------------------------------------------
# Greedy free-running evaluation (exact-match accuracy)
# --------------------------------------------------------------------------

def evaluate(cfg: M.ModelConfig, params: M.Params, lora: M.Lora,
             method: str, eval_name: str, n: int, seq: int,
             seed: int = 1234) -> float:
    """Free-running greedy decode; exact match of the full answer span."""
    task, hard = T.EVALS[eval_name]
    rng = np.random.default_rng(seed)
    toks, _, exs = T.batch(task, rng, n, seq, hard)
    fwd = (M.forward_icarus if method == "icarus"
           else M.forward_conventional)
    fwd_j = jax.jit(lambda tk: fwd(cfg, params, lora, tk))

    # Teacher-forced prompt, then generate autoregressively (batched).
    cur = np.array(toks)
    max_ans = max(len(e.answer) for e in exs)
    starts = np.array([e.prompt_len for e in exs])
    for step_i in range(max_ans):
        logits = np.asarray(fwd_j(jnp.asarray(cur)))
        pos = starts + step_i  # position being generated
        prev = pos - 1
        nxt = logits[np.arange(n), prev].argmax(-1)
        write = pos < seq
        cur[np.arange(n)[write], pos[write]] = nxt[write]
    correct = 0
    for i, e in enumerate(exs):
        span = cur[i, e.prompt_len: e.prompt_len + len(e.answer)]
        if list(span) == e.answer:
            correct += 1
    return 100.0 * correct / n


# --------------------------------------------------------------------------
# Experiment drivers
# --------------------------------------------------------------------------

def run_all(out_dir: str, exps: List[str], steps: int, pre_steps: int,
            batch_size: int, eval_n: int, seq: int, seed: int) -> Dict:
    os.makedirs(out_dir, exist_ok=True)
    results: Dict = {"meta": {
        "steps": steps, "pretrain_steps": pre_steps, "batch": batch_size,
        "eval_n": eval_n, "seq": seq, "seed": seed,
    }}
    bases: Dict[str, M.Params] = {}

    def base_for(cfg):
        if cfg.name not in bases:
            t0 = time.time()
            bases[cfg.name], _ = pretrain_base(
                cfg, pre_steps, batch_size, seq, seed)
            print(f"[pretrain {cfg.name}] {time.time()-t0:.1f}s")
        return bases[cfg.name]

    main_evals = ("gsm8k", "gsm_plus", "heval", "heval_plus", "gpqa")

    if "fig2" in exps:
        cfg = M.TRAIN_BASE
        params = base_for(cfg)
        curves = {}
        for task in ("math", "code"):
            for method in ("conventional", "icarus"):
                _, losses = finetune(cfg, params, task, method, steps,
                                     batch_size, seq, seed)
                curves[f"{task}/{method}"] = losses
                print(f"[fig2 {task}/{method}] final loss {losses[-1]:.4f}")
        results["fig2"] = curves

    if "table2" in exps or "table4" in exps:
        # Train 3 specialists twice (conventional + icarus) on 2 sizes.
        t24 = {}
        for cfg in (M.TRAIN_SMALL, M.TRAIN_BASE):
            params = base_for(cfg)
            entry = {"base": {}, "specialists": {}}
            for ev in main_evals:
                entry["base"][ev] = evaluate(
                    cfg, params, M.zero_lora(cfg), "conventional", ev,
                    eval_n, seq)
            for task in ("math", "code", "know"):
                for method in ("conventional", "icarus"):
                    lora, _ = finetune(cfg, params, task, method, steps,
                                       batch_size, seq, seed)
                    accs = {ev: evaluate(cfg, params, lora, method, ev,
                                         eval_n, seq)
                            for ev in main_evals}
                    entry["specialists"][f"{task}/{method}"] = accs
                    print(f"[table2 {cfg.name} {task}/{method}] {accs}")
            t24[cfg.name] = entry
        results["table2_4"] = t24

    if "table3" in exps:
        t3 = {}
        for cfg in (M.TRAIN_TINY, M.TRAIN_SMALL, M.TRAIN_BASE):
            params = base_for(cfg)
            row = {}
            for method in ("conventional", "icarus"):
                lora, _ = finetune(cfg, params, "math", method, steps,
                                   batch_size, seq, seed)
                row[method] = {
                    "gsm8k": evaluate(cfg, params, lora, method, "gsm8k",
                                      eval_n, seq),
                    "gsm_plus": evaluate(cfg, params, lora, method,
                                         "gsm_plus", eval_n, seq),
                }
            t3[cfg.name] = row
            print(f"[table3 {cfg.name}] {row}")
        results["table3"] = t3

    if "table5" in exps:
        cfg = M.TRAIN_BASE
        params = base_for(cfg)
        t5 = {"curves": {}}
        for method in ("conventional", "icarus"):
            lora, losses = finetune(cfg, params, "tool", method, steps,
                                    batch_size, seq, seed)
            t5["curves"][method] = losses
            t5[method] = {
                "bfcl": evaluate(cfg, params, lora, method, "bfcl",
                                 eval_n, seq),
                "bfcl_plus": evaluate(cfg, params, lora, method,
                                      "bfcl_plus", eval_n, seq),
            }
            print(f"[table5 {method}] {t5[method]}")
        results["table5_fig7"] = t5

    path = os.path.join(out_dir, "accuracy_results.json")
    with open(path, "w") as f:
        json.dump(results, f, indent=2)
    print(f"wrote {path}")
    _write_markdown(results, os.path.join(out_dir, "accuracy_results.md"))
    return results


def export_adapter(cfg, lora, path: str) -> None:
    """Save a trained adapter as npz in the artifact naming convention
    (layers.i.target.{A,B}) so the Rust runtime can serve it directly
    (`PjrtExecutor` consumes the same key layout as `make_adapter`)."""
    arrays = {}
    for i, layer in enumerate(lora):
        for t, (a, b) in layer.items():
            arrays[f"layers.{i}.{t}.A"] = np.asarray(a)
            arrays[f"layers.{i}.{t}.B"] = np.asarray(b)
    np.savez(path, **arrays)
    print(f"wrote {path}")


def _write_markdown(results: Dict, path: str) -> None:
    lines = ["# Accuracy experiments (paper Tables 2-5, Figs 2/7)\n"]
    if "fig2" in results:
        lines.append("## Fig 2 — final training losses\n")
        for k, v in results["fig2"].items():
            lines.append(f"- {k}: first {v[0]:.4f} -> final {v[-1]:.4f}")
        lines.append("")
    if "table2_4" in results:
        for cfgname, entry in results["table2_4"].items():
            lines.append(f"## Table 2/4 — {cfgname}\n")
            evs = list(entry["base"].keys())
            lines.append("| model | " + " | ".join(evs) + " |")
            lines.append("|---|" + "---|" * len(evs))
            lines.append("| base | " + " | ".join(
                f"{entry['base'][e]:.1f}" for e in evs) + " |")
            for name, accs in entry["specialists"].items():
                lines.append(f"| {name} | " + " | ".join(
                    f"{accs[e]:.1f}" for e in evs) + " |")
            # Multi-model rows: route each eval to its home specialist.
            home = {"gsm8k": "math", "gsm_plus": "math", "heval": "code",
                    "heval_plus": "code", "gpqa": "know"}
            for method in ("conventional", "icarus"):
                row = [entry["specialists"][f"{home[e]}/{method}"][e]
                       for e in evs]
                label = ("multi-model" if method == "conventional"
                         else "ICaRus")
                lines.append(f"| {label} (routed) | " + " | ".join(
                    f"{v:.1f}" for v in row) + " |")
            lines.append("")
    if "table3" in results:
        lines.append("## Table 3 — model-size scaling (math)\n")
        lines.append("| config | conv gsm8k | icarus gsm8k | conv gsm+ | icarus gsm+ |")
        lines.append("|---|---|---|---|---|")
        for cfgname, row in results["table3"].items():
            lines.append(
                f"| {cfgname} | {row['conventional']['gsm8k']:.1f} | "
                f"{row['icarus']['gsm8k']:.1f} | "
                f"{row['conventional']['gsm_plus']:.1f} | "
                f"{row['icarus']['gsm_plus']:.1f} |")
        lines.append("")
    if "table5_fig7" in results:
        t5 = results["table5_fig7"]
        lines.append("## Table 5 / Fig 7 — tool calling\n")
        for method in ("conventional", "icarus"):
            if method in t5:
                lines.append(
                    f"- {method}: bfcl {t5[method]['bfcl']:.1f}, "
                    f"bfcl_plus {t5[method]['bfcl_plus']:.1f}")
        lines.append("")
    with open(path, "w") as f:
        f.write("\n".join(lines))
    print(f"wrote {path}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--exp", default="all",
                    help="all | fig2,table2,table3,table5 (comma list)")
    ap.add_argument("--out-dir", default="../experiments")
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--pretrain-steps", type=int, default=150)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--eval-n", type=int, default=200)
    ap.add_argument("--seq", type=int, default=48)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    exps = (["fig2", "table2", "table3", "table4", "table5"]
            if args.exp == "all" else args.exp.split(","))
    run_all(args.out_dir, exps, args.steps, args.pretrain_steps, args.batch,
            args.eval_n, args.seq, args.seed)


if __name__ == "__main__":
    main()
