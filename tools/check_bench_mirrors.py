#!/usr/bin/env python3
"""Guard the repo-root BENCH_<name>.json mirrors.

Every bench writes its rows to bench_results/<name>.json and mirrors
them to BENCH_<name>.json at the repository root so the perf trajectory
is tracked in-tree.  This check fails CI when a mirror is missing,
stale (not rewritten by this run — e.g. a bench stopped mirroring, or a
checked-in mirror is silently rotting), structurally wrong (the "bench"
key does not match the file name), or empty (zero rows).

Usage, from the repo root, after the smoke benches ran:

    touch .bench-stamp            # BEFORE running the benches
    cargo bench --bench <name> -- --smoke   # for each name
    python3 tools/check_bench_mirrors.py --stamp .bench-stamp \
        sched_policies store_tiers overlap cluster_scale serving \
        store_contention
"""

import argparse
import json
import os
import sys


def check(name: str, stamp_mtime: float) -> list[str]:
    path = f"BENCH_{name}.json"
    if not os.path.exists(path):
        return [f"{path}: missing (did `cargo bench --bench {name} -- --smoke` run?)"]
    errors = []
    if stamp_mtime is not None and os.path.getmtime(path) < stamp_mtime:
        errors.append(f"{path}: stale — not rewritten after the stamp (bench stopped mirroring?)")
    try:
        with open(path, encoding="utf-8") as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return errors + [f"{path}: unreadable JSON: {e}"]
    if doc.get("bench") != name:
        errors.append(f"{path}: \"bench\" is {doc.get('bench')!r}, want {name!r}")
    rows = doc.get("rows")
    if not isinstance(rows, list) or not rows:
        errors.append(f"{path}: \"rows\" must be a non-empty list")
    return errors


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("names", nargs="+", help="bench names (BENCH_<name>.json each)")
    ap.add_argument(
        "--stamp",
        help="file touched before the benches ran; mirrors older than it are stale",
    )
    args = ap.parse_args()

    stamp_mtime = None
    if args.stamp:
        if not os.path.exists(args.stamp):
            print(f"stamp file {args.stamp} does not exist", file=sys.stderr)
            return 2
        stamp_mtime = os.path.getmtime(args.stamp)

    failures = []
    for name in args.names:
        failures += check(name, stamp_mtime)
    for f in failures:
        print(f"FAIL {f}", file=sys.stderr)
    if not failures:
        print(f"ok: {len(args.names)} bench mirrors present, fresh and well-formed")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
