#!/usr/bin/env python3
"""Validate a Chrome trace-event / Perfetto JSON file from `--trace-out`.

The exporter (rust/src/obs) writes one process per replica with a
serial compute lane (tid 0, B/E pairs — or X when zero-width) and
X-complete lanes for queue/transfer/handoff/write_back, plus counter
samples (C) on the counter track.  This check fails CI when:

  * the file is not well-formed JSON or "traceEvents" is empty;
  * an event has an unknown phase, a non-integer pid/tid, or (for
    non-metadata phases) a non-numeric ts;
  * timestamps go backwards within a (pid, tid) track in file order —
    viewers tolerate disorder, but the export is documented as
    byte-deterministically sorted, so any disorder is an exporter bug;
  * the compute lane's B/E pairs nest (depth > 1), close without
    opening, or are left open at end of file.  Only tid 0 is checked:
    queue/transfer X spans may legitimately overlap (many sequences
    wait at once);
  * an X event has no numeric dur, or a C event has no args;
  * with --require-kinds, a named span kind never appears as a B/X
    event name (counters do not count).

Usage, on a trace emitted by an obs-on run:

    icarus serve --obs on --trace-out trace.json ...
    python3 tools/check_trace.py trace.json \
        --require-kinds queue,prefill,transfer,handoff,decode,write_back
"""

import argparse
import json
import sys

# Track layout mirrored from rust/src/obs (SpanKind::track): the serial
# compute lane is the only one with begin/end pairs.
COMPUTE_TID = 0

KNOWN_PHASES = ("M", "B", "E", "X", "C")


def check(path: str, require_kinds: set[str]) -> list[str]:
    try:
        with open(path, encoding="utf-8") as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path}: unreadable JSON: {e}"]
    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        return [f'{path}: "traceEvents" must be a non-empty list']

    errors = []
    last_ts: dict[tuple[int, int], float] = {}
    depth: dict[tuple[int, int], int] = {}
    kinds: set[str] = set()
    for i, e in enumerate(events):
        ph = e.get("ph")
        if ph not in KNOWN_PHASES:
            errors.append(f"event {i}: unknown phase {ph!r}")
            continue
        if not isinstance(e.get("pid"), int) or not isinstance(e.get("tid"), int):
            errors.append(f"event {i}: pid/tid must be integers: {e}")
            continue
        if ph == "M":
            continue  # metadata carries no timestamp
        track = (e["pid"], e["tid"])
        ts = e.get("ts")
        if not isinstance(ts, (int, float)) or isinstance(ts, bool):
            errors.append(f"event {i}: ts must be a number: {e}")
            continue
        if ts < last_ts.get(track, float("-inf")):
            errors.append(f"event {i}: ts {ts} goes backwards on track {track}")
        last_ts[track] = ts
        if ph in ("B", "X"):
            kinds.add(e.get("name"))
        if ph == "X" and not isinstance(e.get("dur"), (int, float)):
            errors.append(f"event {i}: X event without numeric dur: {e}")
        if ph == "C" and not isinstance(e.get("args"), dict):
            errors.append(f"event {i}: C event without args: {e}")
        if e["tid"] == COMPUTE_TID and ph in ("B", "E"):
            d = depth.get(track, 0) + (1 if ph == "B" else -1)
            if d not in (0, 1):
                errors.append(
                    f"event {i}: compute lane depth {d} on track {track} "
                    "(B/E unbalanced or nested)"
                )
            depth[track] = d
    for track, d in sorted(depth.items()):
        if d != 0:
            errors.append(f"track {track}: compute lane left open (depth {d})")
    missing = require_kinds - kinds
    if missing:
        have = ", ".join(sorted(k for k in kinds if k)) or "none"
        errors.append(f"{path}: missing span kinds: {', '.join(sorted(missing))} (have: {have})")
    return errors


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("traces", nargs="+", help="trace files to validate")
    ap.add_argument(
        "--require-kinds",
        default="",
        help="comma-separated span names that must each appear as a B/X event",
    )
    args = ap.parse_args()
    require = {k for k in args.require_kinds.split(",") if k}

    failures = []
    for path in args.traces:
        failures += check(path, require)
    for f in failures:
        print(f"FAIL {f}", file=sys.stderr)
    if not failures:
        print(f"ok: {len(args.traces)} trace file(s) well-formed, sorted and balanced")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
